"""Public jit'd wrappers over the Pallas kernels.

Each op handles padding/layout, dispatches to the Pallas kernel (TPU) or its
``interpret=True`` execution (CPU — this container), and exposes exactly the
semantics the pure-jnp oracles in :mod:`repro.kernels.ref` define. Tests
sweep shapes/dtypes asserting allclose against the oracles.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import gpu_lowering as _gpu
from repro.kernels import ref, tuning
from repro.kernels.compact import compact_positions_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.metrics_fused import (BUCKET_BLOCK, TILE,
                                         stream_metrics_carry_pallas,
                                         stream_metrics_pallas)
from repro.kernels.stream_sample import stream_sample_pallas
from repro.kernels.trend_scan import TILE as TREND_TILE
from repro.kernels.trend_scan import (PAIR_TILE, pair_stats_pallas,
                                      trend_scan_carry_pallas,
                                      trend_scan_pallas)


def on_tpu() -> bool:
    """Single source of truth for the device-selection predicate."""
    return jax.default_backend() == "tpu"


def on_gpu() -> bool:
    """True on any CUDA/ROCm device — the Pallas GPU lowering path.

    The scan/accumulate kernels rely on TPU's sequential grid and are
    rerouted to the row-parallel lowerings in
    :mod:`repro.kernels.gpu_lowering`; ``stream_sample`` (whose grid
    steps are independent) compiles unchanged.
    """
    return jax.default_backend() in ("gpu", "cuda", "rocm")


def on_accelerator() -> bool:
    """TPU or GPU: compiled Pallas. Anywhere else the TPU kernels run
    under ``interpret=True`` (this container's CPU tier)."""
    return on_tpu() or on_gpu()


_on_tpu = on_tpu


class PallasDomainError(ValueError):
    """The inputs fall outside the Pallas kernels' exactness domain.

    Raised by the ops wrappers *before* dispatch; ``nsa(backend="pallas")``
    catches it and falls back to the numpy path, so callers only see it
    when invoking the ops layer directly.
    """


class KeepRuleOverflow(PallasDomainError):
    """The systematic keep rule ``(rank * k) % c`` would overflow int32.

    The kernel (and its oracle) compute the Bresenham product in int32 —
    the TPU-native width — which is exact only while ``(c - 1) * k < 2**31``
    for every bucket. Streams with enormous single buckets and weak
    compression (e.g. 100k identical timestamps at multiple ~3) violate
    this; the wrappers refuse them rather than silently diverge from the
    int64 numpy path, and ``nsa(backend="pallas")`` falls back to numpy.
    """


def _pad_to(x: jnp.ndarray, mult: int, value) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])
    return x, n


# --------------------------------------------------------------------- NSA
def _nsa_tables(t64: np.ndarray, max_range: int, multiple: float,
                width: Optional[int] = None):
    """Exact per-bucket tables + kernel inputs for one sorted stream.

    Computes (rebased f32 timestamps, starts, counts, ktab,
    (t_min, 1/span, n_buckets)) where the tables come from the *float64
    host formula* — the identical expression ``(t - t_min) / span *
    max_range`` that :func:`repro.streamsim.nsa.scale_stamps` floors — so
    the kernel's +-1-snapped scale stamps are bit-identical to the numpy
    path. O(n) vectorized host work for ``v`` plus O(max_range log n)
    searchsorted; everything per-record then runs on device.

    ``width`` (default ``max_range``) pads the table axis for range-padded
    sweeps mixing rows at different ``max_range``: tail buckets in
    ``[max_range, width)`` get ``starts = n``, ``counts = 0`` and a ZERO
    keep budget, so they can never claim a record or keep anything — the
    row's compute is fully determined by its ``n_buckets`` scalar.
    """
    from repro.kernels.stream_sample import MAX_RANGE_LIMIT
    if max_range > MAX_RANGE_LIMIT:
        raise PallasDomainError(
            f"max_range {max_range} exceeds {MAX_RANGE_LIMIT}: the +-1 "
            "bucket snap no longer bounds the f32 normalize error; use the "
            "numpy NSA path")
    width = max_range if width is None else width
    assert width >= max_range
    n = len(t64)
    t_min, t_max = float(t64[0]), float(t64[-1])
    span = t_max - t_min
    if span <= 0.0:
        # degenerate stream (all timestamps equal): everything is bucket 0,
        # so bucket 0 spans [0, n) and every later bucket starts at n
        starts = np.full(width, n, np.int32)
        starts[0] = 0
        inv_span = 0.0
    else:
        v = (t64 - t_min) / span * max_range
        starts = np.full(width, n, np.int32)
        starts[:max_range] = np.searchsorted(v, np.arange(max_range))
        inv_span = 1.0 / span
    counts = np.zeros(width, np.int32)
    counts[:max_range] = np.diff(np.append(starts[:max_range], n))
    ktab = np.zeros(width, np.int32)
    ktab[:max_range] = np.clip(
        np.rint(counts[:max_range] / multiple), 1, None)
    prod = (counts.astype(np.int64) - 1).clip(0) * ktab.astype(np.int64)
    if prod.max(initial=0) >= 2 ** 31:
        raise KeepRuleOverflow(
            f"bucket with count={counts[prod.argmax()]} and "
            f"k={ktab[prod.argmax()]} overflows the int32 keep rule; "
            "use the numpy NSA path for this stream")
    t32 = (t64 - t_min).astype(np.float32)
    return t32, starts, counts, ktab, (0.0, inv_span, float(max_range))


def stream_sample(t: jnp.ndarray, max_range: int,
                  multiple: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused NSA inner loop on device (single stream == batch of one).

    t must be sorted ascending. Returns (scale_stamp int32, keep bool), both
    length n. Mirrors repro.streamsim.nsa semantics exactly (keep =
    'systematic', multiple precomputed by the caller).

    Epoch-second timestamps (~1.5e9) quantize to ~128 s in float32, so the
    wrapper re-bases to relative time in float64 *before* the cast. The
    per-bucket tables are computed with the exact float64 host formula and
    the kernel snaps its f32 bucket guess to them, so the outputs are
    bit-identical to the numpy NSA path — not merely allclose.
    """
    t64 = np.asarray(t, np.float64)
    n = len(t64)
    if n == 0:
        return jnp.zeros(0, jnp.int32), jnp.zeros(0, bool)
    t32, starts, counts, ktab, scalars = _nsa_tables(t64, max_range, multiple)
    cfg = tuning.config_for("stream_sample", s=1, n=n, r=max_range)
    tp, n0 = _pad_to(jnp.asarray(t32), cfg.record_tile, t32[-1])
    ss, keep = stream_sample_pallas(
        tp[None, :], jnp.asarray(starts)[None, :],
        jnp.asarray(counts)[None, :], jnp.asarray(ktab)[None, :],
        jnp.asarray(scalars, jnp.float32)[None, :], max_range,
        interpret=not on_accelerator(), config=cfg)
    return ss[0, :n0], keep[0, :n0].astype(bool)


def stream_sample_ref(t: jnp.ndarray, max_range: int, multiple: float):
    """Oracle with the same padding-free public signature."""
    t64 = np.asarray(t, np.float64)
    if len(t64) == 0:
        return jnp.zeros(0, jnp.int32), jnp.zeros(0, bool)
    t32, starts, counts, ktab, scalars = _nsa_tables(t64, max_range, multiple)
    ss, keep = ref.stream_sample_ref(
        jnp.asarray(t32)[None, :], jnp.asarray(starts)[None, :],
        jnp.asarray(counts)[None, :], jnp.asarray(ktab)[None, :],
        jnp.asarray(scalars, jnp.float32)[None, :], max_range)
    return ss[0], keep[0].astype(bool)


def stream_sample_batched(ts, max_range, multiples, *, device=None):
    """Batched fused NSA inner loop: S streams, ONE kernel dispatch.

    ts        : sequence of S sorted 1-D float64 timestamp arrays (ragged
                lengths allowed) or an (S, N) array.
    max_range : int, or a length-S sequence of per-row time ranges — the
                range-padded sweep form: every row normalizes into its OWN
                bucket count while the tables are padded to the sweep's
                maximum (tail buckets carry a zero keep budget), so one
                dispatch covers the whole (stream × max_range) grid.
    multiples : per-stream multiple (scalar broadcasts).
    device    : optional jax device the launch is committed to (the sweep
                engine places each plan shard on its own device; ``None``
                keeps jax's default placement).

    Pads every stream to the common TILE-aligned length and runs the 2-D-grid
    kernel once — replacing S sequential :func:`stream_sample` dispatches.
    Returns (scale_stamp int32 (S, N), keep bool (S, N), lengths int (S,));
    padded tail entries have keep == False. Per row the outputs are
    bit-identical to the single-stream :func:`stream_sample` at that row's
    ``max_range``, whatever the other rows' ranges are.
    """
    ts = [np.asarray(t, np.float64) for t in ts]
    S = len(ts)
    if S == 0:
        raise ValueError("need at least one stream")
    lengths = np.array([len(t) for t in ts])
    if np.any(lengths == 0):
        raise ValueError("batched path requires non-empty streams")
    ranges = np.broadcast_to(np.asarray(max_range, np.int64), (S,))
    if np.any(ranges <= 0):
        raise ValueError("max_range entries must be positive")
    width = int(ranges.max())
    mults = np.broadcast_to(np.asarray(multiples, np.float64), (S,))
    cfg = tuning.config_for("stream_sample", s=S, n=int(lengths.max()),
                            r=width)
    N = int(-(-lengths.max() // cfg.record_tile) * cfg.record_tile)
    t_b = np.empty((S, N), np.float32)
    starts_b = np.empty((S, width), np.int32)
    counts_b = np.empty((S, width), np.int32)
    k_b = np.empty((S, width), np.int32)
    scal_b = np.empty((S, 3), np.float32)
    for s, t64 in enumerate(ts):
        t32, starts, counts, ktab, scalars = _nsa_tables(
            t64, int(ranges[s]), float(mults[s]), width)
        t_b[s, :len(t32)] = t32
        t_b[s, len(t32):] = t32[-1]          # pad into the last bucket
        starts_b[s], counts_b[s], k_b[s] = starts, counts, ktab
        scal_b[s] = scalars

    def _dev(x):
        return jax.device_put(x, device) if device is not None \
            else jnp.asarray(x)

    def _launch(lo, hi):
        return stream_sample_pallas(
            _dev(t_b[lo:hi]), _dev(starts_b[lo:hi]), _dev(counts_b[lo:hi]),
            _dev(k_b[lo:hi]), _dev(scal_b[lo:hi].astype(np.float32)), width,
            interpret=not on_accelerator(), config=cfg)

    g = max(1, min(int(cfg.grid_split), S))
    if g == 1:
        ss, keep = _launch(0, S)
    else:
        # split the row axis into g near-equal launches — smaller grids
        # overlap better with transfers on GPU; per-row outputs are
        # unchanged (each launch sees the identical range-padded tables)
        bounds = [round(i * S / g) for i in range(g + 1)]
        parts = [_launch(a, b)
                 for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
        ss = jnp.concatenate([p[0] for p in parts], axis=0)
        keep = jnp.concatenate([p[1] for p in parts], axis=0)
    valid = jnp.arange(N)[None, :] < _dev(lengths)[:, None]
    return ss, keep.astype(bool) & valid, lengths


# -------------------------------------------------------------- compaction
def compact_mask(mask: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Kept-record indices from a boolean keep mask, on device.

    Chains the Pallas scan-with-carry kernel (exclusive prefix sum over the
    mask -> per-record write position + total) with one XLA scatter that
    lands each kept record's index in its slot — no host round-trip over the
    record axis.

    Returns ``(idx int32 (n,), total int)``: ``idx[:total]`` are the indices
    of the set entries in ascending order; ``idx[total:]`` are ``n``.
    """
    mask = jnp.asarray(mask)
    n = mask.shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32), 0
    cfg = tuning.config_for("compact", s=1, n=n)
    mp, _ = _pad_to(mask.astype(jnp.int32), cfg.record_tile, 0)
    if on_gpu():
        pos, total = _gpu.compact_positions_gpu(mp)
    else:
        pos, total = compact_positions_pallas(mp, interpret=not _on_tpu(),
                                              config=cfg)
    tgt = jnp.where(mask.astype(bool), pos[:n], n)
    idx = jnp.full((n,), n, jnp.int32).at[tgt].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    return idx, int(total[0])


def compact_mask_batched(mask: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                     np.ndarray]:
    """Kept-record indices for R stacked keep masks, ONE device dispatch.

    mask : (R, N) boolean/0-1 keep masks; rows may describe streams of
    different true lengths — the caller masks padded tails to 0 (the
    :func:`stream_sample_batched` ``valid`` mask already does).

    Chains the batched Pallas scan (per-row exclusive prefix sums with the
    SMEM carry reset at each row's first tile) with ONE XLA scatter over the
    whole (R, N) grid — replacing R sequential :func:`compact_mask`
    dispatches.

    Returns ``(idx int32 (R, N), totals int64 (R,))``: ``idx[r, :totals[r]]``
    are row ``r``'s set-entry indices in ascending order; the tail is the
    sentinel ``N`` (the input width — TILE padding is internal and never
    shows up in the output). Per row this matches :func:`compact_mask` on
    that row exactly: same kept indices, same sentinel convention.
    """
    idx, totals = compact_mask_batched_device(mask)
    return idx, np.asarray(totals, np.int64).reshape(-1)


def compact_mask_batched_device(mask: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                            jnp.ndarray]:
    """:func:`compact_mask_batched` with the totals left ON DEVICE.

    Same scan + scatter chain and the same ``idx`` contract, but the
    per-row totals come back as an int32 device array instead of a host
    int64 one — reading them would force a device sync, which the chunked
    pipeline must NOT do at dispatch time (the host reads chunk ``k``'s
    totals only after chunk ``k+1``'s dispatch is in flight).
    """
    from repro.kernels.compact import compact_positions_batched_pallas
    mask = jnp.asarray(mask)
    if mask.ndim != 2:
        raise ValueError(f"mask must be (R, N), got shape {mask.shape}")
    R, n = mask.shape
    if n == 0 or R == 0:
        return jnp.zeros((R, n), jnp.int32), jnp.zeros(R, jnp.int32)
    cfg = tuning.config_for("compact", s=R, n=n)
    pad = (-n) % cfg.record_tile
    mi = mask.astype(jnp.int32)
    if pad:
        mi = jnp.concatenate(
            [mi, jnp.zeros((R, pad), jnp.int32)], axis=1)
    if on_gpu():
        pos, totals = _gpu.compact_positions_batched_gpu(mi)
    else:
        pos, totals = compact_positions_batched_pallas(
            mi, interpret=not _on_tpu(), config=cfg)
    tgt = jnp.where(mask.astype(bool), pos[:, :n], n)
    rows = jnp.arange(R, dtype=jnp.int32)[:, None]
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (R, n))
    idx = jnp.full((R, n), n, jnp.int32).at[rows, tgt].set(cols, mode="drop")
    return idx, totals.reshape(-1)


# -------------------------------------------------------- metrics engine
# int32 histogram accumulation: exact while every bucket count < 2**31
# (the seed's f32 one-hot kernel silently rounded past 2**24)
_HIST_COUNT_LIMIT = 2 ** 31 - 1


def _check_metrics_domain(n_records: int) -> None:
    """A bucket count can at most reach the record count; refuse streams
    whose counts could wrap the int32 accumulator rather than round."""
    if n_records > _HIST_COUNT_LIMIT:
        raise PallasDomainError(
            f"{n_records} records could overflow the int32 histogram "
            f"accumulator (limit {_HIST_COUNT_LIMIT}); use the numpy "
            "metrics path")


def _metrics_padded(ss_list, max_range: int, cfg: tuning.TileConfig):
    """Stack ragged scale-stamp streams into the kernel's (S, N) layout."""
    S = len(ss_list)
    lengths = np.array([len(s) for s in ss_list], np.int64)
    _check_metrics_domain(int(lengths.max(initial=0)))
    tile, block = cfg.record_tile, cfg.bucket_block
    buckets = int(-(-max_range // block) * block)
    N = max(int(-(-lengths.max(initial=1) // tile) * tile), tile)
    ssb = np.full((S, N), buckets, np.int32)     # padding id >= buckets
    for s, row in enumerate(ss_list):
        if len(row) and (row.min() < 0 or row.max() >= max_range):
            raise ValueError(
                f"stream {s}: scale stamps must lie in [0, {max_range})")
        ssb[s, :len(row)] = row
    return ssb, buckets, lengths


def stream_metrics(ss: jnp.ndarray,
                   max_range: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused per-second histogram + count moments, one device pass.

    ss: (n,) integer scale stamps in [0, max_range) (any order; sorted input
    is fastest — see the kernel docstring). Returns
    ``(hist int32 (max_range,), moments f32 (2,) = [Σq, Σq²])``.
    """
    hist, mom, _ = stream_metrics_batched([ss], max_range)
    return hist[0], mom[0]


def stream_metrics_batched(ss_seq, max_range: int):
    """Batched fused metrics: S streams' histograms + moments, ONE dispatch.

    ss_seq: sequence of S 1-D integer scale-stamp arrays (ragged lengths
    allowed; empty streams yield all-zero rows). Returns
    ``(hist int32 (S, max_range), moments f32 (S, 2), lengths int64 (S,))``.
    """
    ss_list = [np.asarray(s, np.int32).reshape(-1) for s in ss_seq]
    if not ss_list:
        raise ValueError("need at least one stream")
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    cfg = tuning.config_for(
        "metrics_fused", s=len(ss_list),
        n=max(int(max(len(s) for s in ss_list)), 1), r=max_range)
    ssb, buckets, lengths = _metrics_padded(ss_list, max_range, cfg)
    if on_gpu():
        hist, mom = _gpu.stream_metrics_gpu(jnp.asarray(ssb), buckets,
                                            bucket_block=cfg.bucket_block)
    else:
        hist, mom = stream_metrics_pallas(jnp.asarray(ssb), buckets,
                                          interpret=not _on_tpu(),
                                          config=cfg)
    return hist[:, :max_range], mom, lengths


def stream_metrics_batched_device(ss: jnp.ndarray, valid_counts,
                                  max_range: int):
    """Fused metrics over scale stamps that are ALREADY device-resident.

    The device-input form of :func:`stream_metrics_batched` — what the
    sweep engine chains straight after the batched NSA compaction so
    kept-stamp sets never round-trip through host between NSA and metrics.

    Parameters
    ----------
    ss : jnp.ndarray, int32, shape (S, N)
        Per-stream scale stamps on device. Row ``s``'s entries at columns
        ``>= valid_counts[s]`` may hold arbitrary garbage (e.g. clipped
        gather output) — they are masked to the kernel's padding id here,
        on device.
    valid_counts : array-like, int, shape (S,)
        Per-row count of valid leading entries. A host array costs one
        O(S) upload; a device array keeps the chain transfer-free.
    max_range : int
        Bucket-axis width; every valid stamp must lie in
        ``[0, max_range)`` (enforced by NSA upstream, not re-checked here
        — a host check would defeat the device residency).

    Returns
    -------
    (hist int32 (S, max_range) device, moments f32 (S, 2) device)
        Bit-identical counts / identical-kernel moments to feeding the
        same stamps through the host-input path.

    Raises
    ------
    PallasDomainError
        If ``N`` (the per-row capacity, an upper bound on any bucket
        count) exceeds the int32 histogram domain.
    """
    ss = jnp.asarray(ss)
    if ss.ndim != 2:
        raise ValueError(f"ss must be (S, N), got shape {ss.shape}")
    if max_range <= 0:
        raise ValueError("max_range must be positive")
    S, N = ss.shape
    _check_metrics_domain(N)
    cfg = tuning.config_for("metrics_fused", s=S, n=max(N, 1), r=max_range)
    tile, block = cfg.record_tile, cfg.bucket_block
    buckets = int(-(-max_range // block) * block)
    nvalid = jnp.asarray(valid_counts, jnp.int32).reshape(S, 1)
    ssb = jnp.where(jnp.arange(N, dtype=jnp.int32)[None, :] < nvalid,
                    ss.astype(jnp.int32), buckets)   # padding id >= buckets
    pad = (-N) % tile
    if pad or N == 0:
        ssb = jnp.concatenate(
            [ssb, jnp.full((S, pad or tile), buckets, jnp.int32)], axis=1)
    if on_gpu():
        hist, mom = _gpu.stream_metrics_gpu(ssb, buckets, bucket_block=block)
    else:
        hist, mom = stream_metrics_pallas(ssb, buckets,
                                          interpret=not _on_tpu(),
                                          config=cfg)
    return hist[:, :max_range], mom


# --------------------------------------------------------------- histogram
def bucket_hist(ss: jnp.ndarray, max_range: int) -> jnp.ndarray:
    """Per-bucket counts of scale stamps; returns (max_range,) int32.

    Legacy wrapper over the fused metrics engine: counts accumulate in int32
    (bit-exact up to 2**31 per bucket — the seed's f32 one-hot kernel lost
    exactness past 2**24) and :class:`PallasDomainError` is raised beyond
    that domain instead of returning silently wrong counts.
    """
    return stream_metrics(ss, max_range)[0]


# -------------------------------------------------------------- volatility
def volatility_moments(q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (Σq, Σq²) over an arbitrary count series.

    When the series comes from scale stamps, prefer :func:`stream_metrics`,
    which produces the histogram AND its moments in the same record pass;
    this reduction (which subsumed the seed's standalone volatility kernel)
    exists for series that are already materialized.
    """
    out = _volatility_moments_jit(jnp.asarray(q, jnp.float32))
    return out[0], out[1]


_volatility_moments_jit = jax.jit(ref.volatility_ref)


def volatility_stats(q: jnp.ndarray) -> Tuple[float, float, float]:
    """(average, variance, std) — device-fused version of formulas (2)-(4)."""
    n = q.shape[0]
    s, s2 = volatility_moments(q)
    avg = s / n
    var = jnp.maximum(s2 / n - avg * avg, 0.0)
    return avg, var, jnp.sqrt(var)


# ------------------------------------------------------- trend & correlation
# int32 prefix-sum accumulation: exact while a stream's total record count
# stays below 2**31 (same bound as the histogram accumulator)
_TREND_TOTAL_LIMIT = 2 ** 31 - 1


def _check_trend_domain(q_list) -> None:
    """Refuse count series outside the int32 scan's exactness domain.

    Both violations raise :class:`PallasDomainError` (not ``ValueError``)
    so the metrics layer falls back to the numpy path for any input the
    device path cannot take — the backends must never diverge on
    acceptance."""
    for s, q in enumerate(q_list):
        if len(q) and int(q.min()) < 0:
            raise PallasDomainError(
                f"stream {s}: negative counts are outside the device trend "
                "domain; use the numpy trend path")
        if int(q.sum(dtype=np.int64)) > _TREND_TOTAL_LIMIT:
            raise PallasDomainError(
                f"stream {s}: total count exceeds the int32 prefix-sum "
                f"domain (limit {_TREND_TOTAL_LIMIT}); use the numpy trend "
                "path")


def _window_tables(lengths: np.ndarray, window: int):
    """Per-stream effective window + half-width (the sliding-mean clamp:
    ``w_eff = clip(min(window, n), 1)``, matching the host semantics of
    ``np.convolve(q, ones(w)/w, mode="same")`` with w clamped to n)."""
    w_eff = np.maximum(np.minimum(window, lengths), 1).astype(np.int32)
    half = ((w_eff - 1) // 2).astype(np.int32)
    return w_eff, half


@jax.jit
def _trend_from_prefix(psum: jnp.ndarray, lengths: jnp.ndarray,
                       w_eff: jnp.ndarray, half: jnp.ndarray) -> jnp.ndarray:
    """Windowed sliding mean from inclusive prefix sums — two clamped
    gathers + one divide, all on device (the XLA tail of the scan kernel,
    as the scatter is to :func:`compact_mask`)."""
    S, N = psum.shape
    i = jnp.arange(N, dtype=jnp.int32)[None, :]
    n = lengths.astype(jnp.int32)[:, None]
    w = w_eff.astype(jnp.int32)[:, None]
    h = half.astype(jnp.int32)[:, None]
    hi = jnp.clip(i + h + 1, 0, n)          # exclusive-prefix index in [0, n]
    lo = jnp.clip(i + h + 1 - w, 0, n)

    def cex(j):                             # c[j] = sum(q[:j]); c[0] = 0
        g = jnp.take_along_axis(psum, jnp.maximum(j - 1, 0), axis=1)
        return jnp.where(j > 0, g, 0)

    win = (cex(hi) - cex(lo)).astype(jnp.float32)    # int32-exact window sums
    out = win / w.astype(jnp.float32)
    return jnp.where(i < n, out, 0.0)


def trend_scan_batched(qs, window: int):
    """Windowed sliding-mean trends of S count series, ONE scan dispatch.

    Parameters
    ----------
    qs : sequence of 1-D integer arrays
        Per-second count series (ragged lengths allowed; empty series yield
        all-zero rows).
    window : int
        Sliding-mean window in (simulated) seconds; per stream it clamps to
        ``max(min(window, n), 1)`` — the host :func:`repro.streamsim.
        metrics.sliding_mean` semantics.

    Returns
    -------
    trend : jnp.ndarray, float32, shape (S, N)
        Per-stream trends on the padded time axis; entries past a stream's
        true length are 0.
    lengths : np.ndarray, int64, shape (S,)
        True series lengths (slice each row with ``trend[s, :lengths[s]]``).

    Raises
    ------
    PallasDomainError
        If any stream's total count exceeds the int32 prefix-sum domain
        (2³¹ − 1). Window sums inside the domain are bit-exact; the final
        divide is f32 (vs. the host path's f64 — well inside the metrics
        layer's 1e-3 tolerance).
    ValueError
        If ``window < 1``, no streams are given, or counts are negative.
    """
    if window < 1:
        raise ValueError("window must be >= 1")
    q_list = [np.asarray(q).reshape(-1) for q in qs]
    if not q_list:
        raise ValueError("need at least one count series")
    _check_trend_domain(q_list)
    lengths = np.array([len(q) for q in q_list], np.int64)
    cfg = tuning.config_for("trend_scan", s=len(q_list),
                            n=int(lengths.max(initial=1)))
    tile = cfg.record_tile
    N = max(int(-(-lengths.max(initial=1) // tile) * tile), tile)
    qb = np.zeros((len(q_list), N), np.int32)
    for s, q in enumerate(q_list):
        qb[s, :len(q)] = q
    if on_gpu():
        psum = _gpu.trend_scan_gpu(jnp.asarray(qb))
    else:
        psum = trend_scan_pallas(jnp.asarray(qb), interpret=not _on_tpu(),
                                 config=cfg)
    w_eff, half = _window_tables(lengths, window)
    trend = _trend_from_prefix(psum, jnp.asarray(lengths),
                               jnp.asarray(w_eff), jnp.asarray(half))
    return trend, lengths


def trend_scan(q: jnp.ndarray, window: int) -> jnp.ndarray:
    """Windowed sliding-mean trend of one count series, on device.

    Single-stream convenience over :func:`trend_scan_batched` (a batch of
    one). Returns a float32 ``(n,)`` device array; same domain guards.
    """
    trend, lengths = trend_scan_batched([q], window)
    return trend[0, :int(lengths[0])]


def trend_scan_batched_device(qmat: jnp.ndarray, lengths, window: int,
                              totals=None):
    """Device-input form of :func:`trend_scan_batched`.

    qmat : (S, N) int32 count series already on device, zero-padded past
    each row's true length (the fused metrics engine's histograms are
    exactly this shape). ``lengths`` gives the true series lengths (host).
    ``totals`` — per-row total record counts for the int32 prefix-sum
    domain guard; the caller (who produced the counts) knows them as O(S)
    host scalars, so the guard costs no device→host transfer of the count
    matrix itself. ``None`` skips the guard — only for counts whose totals
    are already bounded elsewhere.

    Returns ``(trend f32 (S, N) device, lengths int64 (S,))``; same
    contract as the host-input form.
    """
    if window < 1:
        raise ValueError("window must be >= 1")
    qmat = jnp.asarray(qmat)
    if qmat.ndim != 2:
        raise ValueError(f"qmat must be (S, N), got shape {qmat.shape}")
    lengths = np.asarray(lengths, np.int64).reshape(-1)
    if len(lengths) != qmat.shape[0]:
        raise ValueError("lengths must align with qmat rows")
    if totals is not None:
        totals = np.asarray(totals, np.int64).reshape(-1)
        if np.any(totals > _TREND_TOTAL_LIMIT):
            raise PallasDomainError(
                "total count exceeds the int32 prefix-sum domain "
                f"(limit {_TREND_TOTAL_LIMIT}); use the numpy trend path")
    S, N = qmat.shape
    cfg = tuning.config_for("trend_scan", s=S, n=max(N, 1))
    tile = cfg.record_tile
    pad = (-N) % tile
    if pad or N == 0:
        qmat = jnp.concatenate(
            [qmat.astype(jnp.int32),
             jnp.zeros((S, pad or tile), jnp.int32)], axis=1)
    if on_gpu():
        psum = _gpu.trend_scan_gpu(qmat.astype(jnp.int32))
    else:
        psum = trend_scan_pallas(qmat.astype(jnp.int32),
                                 interpret=not _on_tpu(), config=cfg)
    w_eff, half = _window_tables(lengths, window)
    trend = _trend_from_prefix(psum, jnp.asarray(lengths),
                               jnp.asarray(w_eff), jnp.asarray(half))
    return trend, lengths


def trend_pair_stats(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """All-pairs Pearson sufficient statistics of stacked trend series.

    Parameters
    ----------
    x : jnp.ndarray, float32, shape (S, K)
        Trend series on a common time grid (pad tails with 0 — zeros
        contribute nothing to any statistic).

    Returns
    -------
    sums : jnp.ndarray, float32, shape (S, 1)
        ``sums[s] = Σ_t x[s, t]``.
    gram : jnp.ndarray, float32, shape (S, S)
        ``gram[a, b] = Σ_t x[a, t]·x[b, t]`` — with ``sums`` this is the
        ``[Σx, Σy, Σxy, Σx², Σy²]`` bundle for every stream pair.
    """
    x = jnp.asarray(x, jnp.float32)
    if x.ndim != 2 or x.shape[0] < 1:
        raise ValueError("x must be (S, K) with S >= 1")
    k = x.shape[1]
    cfg = tuning.config_for("pair_stats", s=x.shape[0], n=max(k, 1))
    pair_tile = cfg.bucket_block
    pad = (-k) % pair_tile
    if pad or k == 0:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad or pair_tile), x.dtype)], axis=1)
    if on_gpu():
        return _gpu.pair_stats_gpu(x)
    return pair_stats_pallas(x, interpret=not _on_tpu(), config=cfg)


@functools.partial(jax.jit, static_argnames=("n_points",))
def _resample_uniform(x: jnp.ndarray, lengths: jnp.ndarray,
                      n_points: int) -> jnp.ndarray:
    """Linear resample of each (ragged) trend row onto a uniform grid of
    ``n_points`` — the device mirror of ``np.interp(linspace(0, 1, K),
    linspace(0, 1, n), row)``: lerp at position ``i·(n−1)/(K−1)``."""
    n = lengths.astype(jnp.float32)[:, None]
    i = jnp.arange(n_points, dtype=jnp.float32)[None, :]
    scale = (n - 1.0) / max(n_points - 1, 1)   # n_points == 1 -> pos stays 0
    pos = i * scale
    j = jnp.floor(pos).astype(jnp.int32)
    j = jnp.clip(j, 0, jnp.maximum(lengths.astype(jnp.int32)[:, None] - 2, 0))
    frac = pos - j.astype(jnp.float32)
    x0 = jnp.take_along_axis(x, j, axis=1)
    x1 = jnp.take_along_axis(
        x, jnp.minimum(j + 1, jnp.maximum(
            lengths.astype(jnp.int32)[:, None] - 1, 0)), axis=1)
    return x0 * (1.0 - frac) + x1 * frac


def _corr_from_gram(gram, live, S: int) -> np.ndarray:
    """Normalize a centered Gram matrix into the S×S Pearson matrix.

    The single source of the output contract — exact symmetry, clip to
    [-1, 1], unit diagonal for non-zero variance, NaN rows for empty or
    zero-variance streams — shared by the device path below and the f64
    numpy mirror (``repro.streamsim.metrics._corr_matrix_numpy``), so the
    two backends can never drift apart on convention. ``live`` indexes the
    non-empty streams ``gram`` covers within the full S×S output.
    """
    corr = np.full((S, S), np.nan)
    g = np.asarray(gram, np.float64)
    g = (g + g.T) / 2.0                       # exact symmetry
    d = np.sqrt(np.clip(np.diag(g), 0.0, None))
    denom = np.outer(d, d)
    with np.errstate(invalid="ignore", divide="ignore"):
        sub = np.where(denom > 0, g / np.where(denom > 0, denom, 1.0),
                       np.nan)
    np.clip(sub, -1.0, 1.0, out=sub)
    np.fill_diagonal(sub, np.where(d > 0, 1.0, np.nan))
    corr[np.ix_(live, live)] = sub
    return corr


def trend_correlation_batched(qs, window: int,
                              n_points: Optional[int] = None) -> np.ndarray:
    """S×S trend-correlation matrix from ONE batched device dispatch chain.

    The full Fig.-6 validation path on device: count series → prefix-sum
    scan (:func:`trend_scan_batched`) → sliding-mean trends → linear
    resample onto a common grid → mean-centering → all-pairs sufficient
    statistics (:func:`trend_pair_stats`, one Gram-matrix dispatch). Only
    the final ``O(S²)`` normalization runs on host, in float64.

    Parameters
    ----------
    qs : sequence of 1-D integer arrays
        Per-second count series, ragged lengths allowed.
    window : int
        Sliding-mean window (see :func:`trend_scan_batched`).
    n_points : int, optional
        Common resampling grid size. Defaults to the shortest non-empty
        series' length — for S = 2 this reproduces the pairwise host
        convention of :func:`repro.streamsim.metrics.
        trend_correlation_from_counts` exactly.

    Returns
    -------
    corr : np.ndarray, float64, shape (S, S)
        Symmetric Pearson matrix, clipped to [-1, 1], diagonal exactly 1
        for streams with non-zero trend variance. Rows/columns of empty or
        zero-variance streams are NaN (matching the pairwise convention).

    Raises
    ------
    PallasDomainError
        Propagated from :func:`trend_scan_batched`; callers that want the
        numpy fallback should catch it (``repro.streamsim.metrics.
        trend_correlation_matrix`` does).
    """
    trend, lengths = trend_scan_batched(qs, window)
    return _corr_from_trends(trend, lengths, n_points)


def _corr_from_trends(trend: jnp.ndarray, lengths: np.ndarray,
                      n_points: Optional[int]) -> np.ndarray:
    """Shared tail of the S×S matrix paths: trends → common-grid resample
    → centering → Gram kernel → host f64 normalization."""
    S = len(lengths)
    live = np.flatnonzero(lengths > 0)
    if len(live) == 0:
        return np.full((S, S), np.nan)
    K = int(n_points) if n_points is not None else int(lengths[live].min())
    if K < 1:
        raise ValueError("n_points must be >= 1")
    z = _resample_uniform(trend[live], jnp.asarray(lengths[live]), K)
    z = z - jnp.mean(z, axis=1, keepdims=True)
    _, gram = trend_pair_stats(z)
    return _corr_from_gram(gram, live, S)


def trend_correlation_batched_device(qmat: jnp.ndarray, lengths,
                                     window: int,
                                     n_points: Optional[int] = None,
                                     totals=None) -> np.ndarray:
    """S×S trend-correlation matrix from count series ALREADY on device.

    The device-input form of :func:`trend_correlation_batched`: the sweep
    engine feeds it the fused metrics engine's histogram rows directly, so
    the whole Fig.-6 chain — counts → scan → trends → resample → Gram —
    never moves the count matrix through host. Same output contract and
    the same O(S²) host-side f64 normalization at the end; ``totals``
    drives the int32 domain guard as in
    :func:`trend_scan_batched_device`.
    """
    trend, lengths = trend_scan_batched_device(qmat, lengths, window,
                                               totals=totals)
    return _corr_from_trends(trend, lengths, n_points)


# ------------------------------------------------- pairwise trend correlation
@functools.partial(jax.jit, static_argnames=("k_max",))
def _pairwise_corr_jit(qa, la, wa, ha, ai, qb, lb, wb, hb, kk, k_max: int):
    """P (original, simulated) pairs → P Pearson r's, one fused XLA chain.

    ``qa`` holds the D *unique* left-side series (e.g. one per dataset)
    and ``ai`` maps each pair to its left row, so every unique left
    trend is computed ONCE — the per-scenario host loop recomputed the
    original's full-day sliding mean for every (dataset, max_range) cell.
    Per pair: int32 prefix sums (exact — same domain as the scan kernel)
    → sliding-mean trends (`_trend_from_prefix` tail) → both series
    linearly resampled onto the pair's OWN ``min(n_a, n_b)``-point grid
    (matching the host pairwise convention of
    ``trend_correlation_from_counts``, where every pair gets its own
    grid; the left resample gathers straight from the unique trend rows,
    never materializing a (P, Na) copy) → masked mean-centering →
    Pearson. Ragged grids ride one padded (P, k_max) lane space with
    per-row valid masks, so the whole report statistic is ONE device
    program instead of a per-scenario host loop.
    """
    ta_u = _trend_from_prefix(jnp.cumsum(qa, axis=1, dtype=jnp.int32),
                              la, wa, ha)                  # (D, Na) once
    tb = _trend_from_prefix(jnp.cumsum(qb, axis=1, dtype=jnp.int32),
                            lb, wb, hb)

    def grid(n, k):
        n = n.astype(jnp.float32)[:, None]
        k = k.astype(jnp.float32)[:, None]
        i = jnp.arange(k_max, dtype=jnp.float32)[None, :]
        pos = i * (n - 1.0) / jnp.maximum(k - 1.0, 1.0)
        nn = n.astype(jnp.int32)
        j = jnp.clip(pos.astype(jnp.int32), 0, jnp.maximum(nn - 2, 0))
        frac = pos - j.astype(jnp.float32)
        j1 = jnp.minimum(j + 1, jnp.maximum(nn - 1, 0))
        return j, j1, frac

    i_lane = jnp.arange(k_max, dtype=jnp.int32)[None, :]
    kkc = kk.astype(jnp.int32)[:, None]
    valid = i_lane < kkc

    # left side: gather K points per pair from the unique trend rows
    ja, ja1, fa = grid(la[ai], kk)
    ra = ta_u[ai[:, None], ja] * (1.0 - fa) + ta_u[ai[:, None], ja1] * fa
    # right side: one row per pair already
    jb, jb1, fb = grid(lb, kk)
    rb = jnp.take_along_axis(tb, jb, axis=1) * (1.0 - fb) + \
        jnp.take_along_axis(tb, jb1, axis=1) * fb
    ra = jnp.where(valid, ra, 0.0)
    rb = jnp.where(valid, rb, 0.0)

    denom_k = jnp.maximum(kkc.astype(jnp.float32), 1.0)
    ra = jnp.where(valid, ra - jnp.sum(ra, axis=1, keepdims=True) / denom_k,
                   0.0)
    rb = jnp.where(valid, rb - jnp.sum(rb, axis=1, keepdims=True) / denom_k,
                   0.0)
    num = jnp.sum(ra * rb, axis=1)
    den = jnp.sum(ra * ra, axis=1) * jnp.sum(rb * rb, axis=1)
    r = num / jnp.sqrt(den)
    return jnp.where((den > 0.0) & (kk > 0), jnp.clip(r, -1.0, 1.0),
                     jnp.nan)


def trend_corr_pairwise(qa: jnp.ndarray, lengths_a, qb: jnp.ndarray,
                        lengths_b, window: int, totals=None,
                        a_index=None) -> np.ndarray:
    """Pairwise trend correlations for P (original, simulated) pairs.

    The batched device form of the per-report statistic
    ``trend_correlation_from_counts(original_counts, simulated_counts)``:
    P pairs in one fused XLA chain, instead of P sequential host
    sliding-mean/resample/Pearson passes. Pure XLA (int32 ``cumsum`` +
    the shared ``_trend_from_prefix`` tail) — device-resident without a
    Pallas leg, so it is fast in CPU tests too. When several pairs share
    a left-side series (every max_range of a sweep correlates against
    the SAME original), pass the unique rows plus ``a_index``: each
    unique trend is computed once and gathered per pair, where the host
    loop recomputed it per scenario.

    Parameters
    ----------
    qa : jnp.ndarray, int32, shape (D, Na)
        Unique left-side count rows on device (zero-padded tails) —
        ``D == P`` with ``a_index=None``.
    qb : jnp.ndarray, int32, shape (P, Nb)
        Right-side count rows (one per pair) — e.g. the fused metrics
        engine's histograms for the sims.
    lengths_a, lengths_b : array-like int, shape (D,) / (P,)
        True series lengths per row (host).
    window : int
        Sliding-mean window shared by both sides (>= 1).
    totals : array-like int, optional
        Per-row max total counts for the int32 domain guard (raises
        :class:`PallasDomainError` when exceeded).
    a_index : array-like int, shape (P,), optional
        Pair → left-row map; ``None`` means the identity (``D == P``).

    Returns
    -------
    np.ndarray, float64, shape (P,)
        Pearson r per pair, NaN for empty or zero-variance pairs — the
        host convention, within the documented 1e-3 f32 tolerance.
    """
    if window < 1:
        raise ValueError("window must be >= 1")
    la = np.asarray(lengths_a, np.int64).reshape(-1)
    lb = np.asarray(lengths_b, np.int64).reshape(-1)
    qa, qb = jnp.asarray(qa), jnp.asarray(qb)
    if a_index is None:
        a_index = np.arange(len(la))
    ai = np.asarray(a_index, np.int64).reshape(-1)
    if qa.ndim != 2 or qb.ndim != 2 or len(ai) != qb.shape[0] or \
            len(la) != qa.shape[0] or len(lb) != qb.shape[0]:
        raise ValueError("qa/qb must be 2-D with aligned lengths/index")
    if len(ai) and (ai.min() < 0 or ai.max() >= len(la)):
        raise ValueError("a_index out of range")
    if totals is not None:
        totals = np.asarray(totals, np.int64).reshape(-1)
        if np.any(totals > _TREND_TOTAL_LIMIT):
            raise PallasDomainError(
                "total count exceeds the int32 prefix-sum domain "
                f"(limit {_TREND_TOTAL_LIMIT}); use the numpy trend path")
    kk = np.minimum(la[ai], lb)
    k_max = max(int(kk.max(initial=1)), 1)
    wa, ha = _window_tables(la, window)
    wb, hb = _window_tables(lb, window)
    r = _pairwise_corr_jit(qa.astype(jnp.int32), jnp.asarray(la),
                           jnp.asarray(wa), jnp.asarray(ha),
                           jnp.asarray(ai),
                           qb.astype(jnp.int32), jnp.asarray(lb),
                           jnp.asarray(wb), jnp.asarray(hb),
                           jnp.asarray(kk), k_max)
    return np.asarray(r, np.float64)


# ------------------------------------------------------------- chunk carry
@dataclasses.dataclass
class ChunkCarry:
    """Device-resident cross-chunk carry state for the chunked sweep.

    The chunked pipeline splits each scenario's simulated timeline into
    fixed-size scale-stamp chunks (chunk ``k`` owns the absolute bucket
    range ``[k·chunk_s, (k+1)·chunk_s)``); because chunks partition the
    BUCKET axis, per-chunk outputs compose exactly:

    ``hist``       (S, width) int32 — the running absolute-bucket histogram;
                   each chunk's slice lands at its own column range, so the
                   finalized histogram is bit-identical to the monolithic
                   kernel's.
    ``mom``        (S, 4) f32 — the pairwise+Kahan moment state
                   ``[s1, c1, s2, c2]`` (``Σq`` / ``Σq²`` plus their
                   compensation terms), folded in-kernel chunk by chunk;
                   carrying the compensations keeps the error O(1) ulp
                   regardless of chunk count (the documented ~1e-5).
    ``psum_tail``  (S,) int32 — the inclusive prefix-sum total through the
                   last folded bucket (the trend scan kernel's carry-in).
    ``trend_tail`` (S, w-1) int32 — the last ``w-1`` bucket counts, i.e.
                   exactly the history a ``w``-second sliding-mean window
                   still needs once the next chunk arrives.

    All four live on device; only ``window``/``next_lo`` are host
    bookkeeping. Nothing here is ever transferred between chunks.
    """

    hist: jnp.ndarray
    mom: jnp.ndarray
    psum_tail: jnp.ndarray
    trend_tail: jnp.ndarray
    window: int
    next_lo: int = 0


def chunk_carry_init(n_rows: int, width: int, window: int = 1) -> ChunkCarry:
    """Fresh all-zero carry for ``n_rows`` scenario rows and a ``width``-
    bucket sweep axis. Per-scenario isolation is by construction: every
    scenario row has its own carry lane, and a new sweep (or a new scenario
    batch) starts from a new ``chunk_carry_init`` — never from a reused
    carry."""
    if n_rows < 1 or width < 1:
        raise ValueError("need n_rows >= 1 and width >= 1")
    w = max(int(window), 1)
    return ChunkCarry(
        hist=jnp.zeros((n_rows, width), jnp.int32),
        mom=jnp.zeros((n_rows, 4), jnp.float32),
        psum_tail=jnp.zeros((n_rows,), jnp.int32),
        trend_tail=jnp.zeros((n_rows, w - 1), jnp.int32),
        window=w)


def stream_metrics_chunk(carry: ChunkCarry, ss: jnp.ndarray, valid_counts,
                         lo: int, hi: int) -> ChunkCarry:
    """Fold one chunk's kept scale stamps into the carry — all on device.

    Parameters
    ----------
    carry : ChunkCarry
        State after the previous chunk (``chunk_carry_init`` for the
        first).
    ss : jnp.ndarray, int32, shape (S, N)
        ABSOLUTE scale stamps of this chunk's kept records, device-
        resident; row ``s``'s entries past ``valid_counts[s]`` may hold
        garbage (clipped gather output). Valid stamps must lie in
        ``[lo, hi)`` — guaranteed by NSA upstream, not re-checked here (a
        host check would defeat the device residency).
    valid_counts : array-like int, shape (S,)
        Per-row kept-record count for this chunk; a DEVICE array keeps the
        dispatch sync-free.
    lo, hi : int
        The chunk's absolute bucket range (``hi - lo`` buckets, ragged
        last chunk allowed); consecutive calls must tile the bucket axis
        in order.

    Returns a new :class:`ChunkCarry`: the chunk histogram (from the
    carried-Kahan metrics kernel) lands at columns ``[lo, hi)`` of
    ``hist``; ``mom`` is the kernel's updated Kahan state; ``psum_tail`` /
    ``trend_tail`` advance so the trend scan can continue seamlessly.
    """
    ss = jnp.asarray(ss)
    if ss.ndim != 2:
        raise ValueError(f"ss must be (S, N), got shape {ss.shape}")
    cw = int(hi) - int(lo)
    if cw <= 0:
        raise ValueError(f"empty chunk range [{lo}, {hi})")
    if lo != carry.next_lo:
        raise ValueError(
            f"chunk [{lo}, {hi}) out of order: carry expects lo == "
            f"{carry.next_lo} (chunks must tile the bucket axis in order)")
    if hi > carry.hist.shape[1]:
        raise ValueError(f"chunk [{lo}, {hi}) exceeds the carry's "
                         f"{carry.hist.shape[1]}-bucket axis")
    S, N = ss.shape
    _check_metrics_domain(N)
    cfg = tuning.config_for("metrics_fused", s=S, n=max(N, 1), r=cw)
    tile, block = cfg.record_tile, cfg.bucket_block
    buckets = int(-(-cw // block) * block)
    nvalid = jnp.asarray(valid_counts, jnp.int32).reshape(S, 1)
    local = ss.astype(jnp.int32) - jnp.int32(lo)     # chunk-local bucket ids
    ssb = jnp.where(jnp.arange(N, dtype=jnp.int32)[None, :] < nvalid,
                    local, buckets)                  # padding id >= buckets
    pad = (-N) % tile
    if pad or N == 0:
        ssb = jnp.concatenate(
            [ssb, jnp.full((S, pad or tile), buckets, jnp.int32)], axis=1)
    if on_gpu():
        hist_c, mom = _gpu.stream_metrics_carry_gpu(ssb, carry.mom, buckets,
                                                    bucket_block=block)
    else:
        hist_c, mom = stream_metrics_carry_pallas(ssb, carry.mom, buckets,
                                                  interpret=not _on_tpu(),
                                                  config=cfg)
    chunk_q = hist_c[:, :cw]
    hist = jax.lax.dynamic_update_slice(carry.hist, chunk_q, (0, lo))
    psum_tail = carry.psum_tail + jnp.sum(chunk_q, axis=1, dtype=jnp.int32)
    w = carry.window
    if w > 1:
        ext = jnp.concatenate([carry.trend_tail, chunk_q], axis=1)
        trend_tail = ext[:, -(w - 1):]
    else:
        trend_tail = carry.trend_tail
    return dataclasses.replace(carry, hist=hist, mom=mom,
                               psum_tail=psum_tail, trend_tail=trend_tail,
                               next_lo=int(hi))


def chunk_carry_finalize(carry: ChunkCarry) -> Tuple[jnp.ndarray,
                                                     jnp.ndarray]:
    """(hist int32 (S, width), moments f32 (S, 2)) — the monolithic
    engine's output shapes, recovered from a fully-folded carry: counts
    bit-identical to one whole-timeline dispatch, moments within the
    documented ~1e-5 (the Kahan fold sees the same buckets in the same
    block order, just split across launches)."""
    return carry.hist, carry.mom[:, ::2]


def trend_scan_chunk(q_chunk: jnp.ndarray, window: int, *, tail=None,
                     psum_carry=None, lo: int = 0, is_last: bool = False):
    """Streaming sliding-mean trend: emit the positions a chunk completes.

    The chunked counterpart of :func:`trend_scan_batched_device` for one
    time chunk of the count series. A centered ``w``-window at position
    ``p`` reaches ``half = (w-1)//2`` buckets PAST ``p``, so the emission
    frontier lags the fold frontier by ``half`` positions: after folding
    buckets ``[lo, lo+c)`` the positions ``[max(lo-half, 0), lo+c-half)``
    have their full window available (``is_last=True`` flushes the final
    ``half`` clamped positions). Window sums are int32-exact (the carry
    form of the scan kernel seeds its SMEM carry from ``psum_carry``), so
    concatenating the emitted segments over all chunks is BIT-identical to
    the monolithic trend — provided the total series length is >=
    ``window`` (the monolithic path clamps ``w`` to short series; a
    streaming consumer cannot know the final length mid-stream, so this op
    requires the un-clamped regime).

    Parameters
    ----------
    q_chunk : (S, c) int32 device — this chunk's bucket counts (uniform
        row length; the sweep's aligned chunk grid guarantees this).
    window : int — sliding-mean window ``w`` (>= 1).
    tail : (S, w-1) int32 device — the previous carry's ``trend_tail``
        (``None`` = zeros, first chunk).
    psum_carry : (S,) int32 device — the previous carry's ``psum_tail``
        (``None`` = zeros).
    lo : int — the chunk's first absolute bucket id.
    is_last : bool — flush the final ``half`` positions.

    Returns ``(seg f32 (S, m), start, new_tail, new_total)`` where ``seg``
    covers global trend positions ``[start, start + m)`` (``m`` may be 0
    for a tiny first chunk), and ``new_tail``/``new_total`` feed the next
    call.
    """
    w = int(window)
    if w < 1:
        raise ValueError("window must be >= 1")
    q_chunk = jnp.asarray(q_chunk, jnp.int32)
    if q_chunk.ndim != 2:
        raise ValueError(f"q_chunk must be (S, c), got {q_chunk.shape}")
    S, c = q_chunk.shape
    if tail is None:
        tail = jnp.zeros((S, w - 1), jnp.int32)
    tail = jnp.asarray(tail, jnp.int32)
    if tail.shape != (S, w - 1):
        raise ValueError(f"tail must be (S, {w - 1}), got {tail.shape}")
    if psum_carry is None:
        psum_carry = jnp.zeros((S,), jnp.int32)
    psum_carry = jnp.asarray(psum_carry, jnp.int32).reshape(S)

    # ext covers global buckets [lo - (w-1), lo + c): every window any
    # emittable position needs. Leading zeros (first chunks) reproduce the
    # monolithic lo-clamp exactly — zero counts add nothing to any window.
    ext = jnp.concatenate([tail, q_chunk], axis=1)        # (S, w-1+c)
    base = psum_carry - jnp.sum(tail, axis=1, dtype=jnp.int32)
    n_ext = ext.shape[1]
    cfg = tuning.config_for("trend_scan", s=S, n=max(n_ext, 1))
    tile = cfg.record_tile
    pad = (-n_ext) % tile
    if pad or n_ext == 0:
        ext_p = jnp.concatenate(
            [ext, jnp.zeros((S, pad or tile), jnp.int32)], axis=1)
    else:
        ext_p = ext
    if on_gpu():
        cinc, _ = _gpu.trend_scan_carry_gpu(ext_p, base)
    else:
        cinc, _ = trend_scan_carry_pallas(ext_p, base,
                                          interpret=not _on_tpu(),
                                          config=cfg)
    cinc = cinc[:, :n_ext]                  # inclusive global prefix sums

    half = (w - 1) // 2
    hi_abs = lo + c
    e0 = max(lo - half, 0)
    e1 = hi_abs if is_last else max(hi_abs - half, e0)
    new_tail = ext[:, -(w - 1):] if w > 1 else tail
    new_total = psum_carry + jnp.sum(q_chunk, axis=1, dtype=jnp.int32)
    m = e1 - e0
    if m <= 0:
        return jnp.zeros((S, 0), jnp.float32), e0, new_tail, new_total

    p = jnp.arange(e0, e1, dtype=jnp.int32)[None, :]      # global positions
    # local (ext) indices of the window's exclusive-prefix bounds
    jhi = jnp.minimum(p + half + 1, hi_abs) - lo + (w - 1)
    jlo = p + half - lo                                   # >= 0 by e0 choice

    def cex(j):                             # exclusive prefix at local j
        jb = jnp.broadcast_to(j, (S, m))
        g = jnp.take_along_axis(cinc, jnp.maximum(jb - 1, 0), axis=1)
        return jnp.where(jb > 0, g, base[:, None])

    win = (cex(jhi) - cex(jlo)).astype(jnp.float32)
    return win / jnp.float32(w), e0, new_tail, new_total


# ------------------------------------------------------------ flash decode
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray, *, block_s: int = 512) -> jnp.ndarray:
    """Blocked online-softmax GQA decode attention (see kernel docstring).

    Pads the cache axis to a block multiple; padded positions are masked by
    ``lengths`` automatically.
    """
    s = k.shape[1]
    pad = (-s) % block_s
    if pad:
        zk = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    return flash_decode_pallas(q, k, v, lengths, block_s=block_s,
                               interpret=not _on_tpu())


__all__ = [
    "ChunkCarry", "KeepRuleOverflow", "PallasDomainError", "bucket_hist",
    "chunk_carry_finalize", "chunk_carry_init", "compact_mask",
    "compact_mask_batched", "compact_mask_batched_device", "flash_decode",
    "on_accelerator", "on_gpu", "on_tpu",
    "stream_metrics", "stream_metrics_chunk", "trend_scan_chunk",
    "stream_metrics_batched", "stream_metrics_batched_device",
    "stream_sample", "stream_sample_batched", "stream_sample_ref",
    "trend_corr_pairwise", "trend_correlation_batched",
    "trend_correlation_batched_device", "trend_pair_stats", "trend_scan",
    "trend_scan_batched", "trend_scan_batched_device", "volatility_moments",
    "volatility_stats",
]
