"""Public jit'd wrappers over the Pallas kernels.

Each op handles padding/layout, dispatches to the Pallas kernel (TPU) or its
``interpret=True`` execution (CPU — this container), and exposes exactly the
semantics the pure-jnp oracles in :mod:`repro.kernels.ref` define. Tests
sweep shapes/dtypes asserting allclose against the oracles.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bucket_hist import LANE, TILE, bucket_hist_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.stream_sample import stream_sample_pallas
from repro.kernels.volatility import volatility_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mult: int, value) -> Tuple[jnp.ndarray, int]:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])
    return x, n


# --------------------------------------------------------------------- NSA
def stream_sample(t: jnp.ndarray, max_range: int,
                  multiple: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused NSA inner loop on device.

    t must be sorted ascending. Returns (scale_stamp int32, keep bool), both
    length n. Mirrors repro.streamsim.nsa semantics exactly (keep =
    'systematic', multiple precomputed by the caller).

    Epoch-second timestamps (~1.5e9) quantize to ~128 s in float32, so the
    wrapper re-bases to relative time in float64 *before* the cast — the
    kernel then works at ~10 ms resolution over a day-long stream. Records
    within float32-eps of a bucket edge may still bucket differently from the
    float64 host path (≪0.1%); the oracle uses the identical f32 path so
    kernel-vs-oracle is exact.
    """
    t = np.asarray(t, np.float64)
    t = jnp.asarray(t - t[0] if len(t) else t, jnp.float32)
    n = t.shape[0]
    if n == 0:
        return jnp.zeros(0, jnp.int32), jnp.zeros(0, bool)
    t_min = t[0]
    span = jnp.maximum(t[-1] - t[0], 1e-9)
    # per-bucket tables: O(max_range) via searchsorted on the sorted column
    edges = t_min + span * jnp.arange(max_range + 1, dtype=jnp.float32) / max_range
    starts_full = jnp.searchsorted(t, edges[:-1], side="left").astype(jnp.int32)
    ends = jnp.searchsorted(t, edges[1:], side="left").astype(jnp.int32)
    counts = (ends - starts_full).astype(jnp.int32)
    # the clamp (record at t_max) folds into the last bucket
    counts = counts.at[-1].add(n - ends[-1])
    tp, n0 = _pad_to(t, TILE, jnp.inf)
    ss, keep = stream_sample_pallas(
        tp, starts_full, counts, t_min, span,
        jnp.float32(multiple), max_range,
        interpret=not _on_tpu())
    return ss[:n0], keep[:n0].astype(bool)


def stream_sample_ref(t: jnp.ndarray, max_range: int, multiple: float):
    """Oracle with the same padding-free public signature."""
    t = np.asarray(t, np.float64)
    t = jnp.asarray(t - t[0] if len(t) else t, jnp.float32)
    n = t.shape[0]
    t_min = t[0]
    span = jnp.maximum(t[-1] - t[0], 1e-9)
    edges = t_min + span * jnp.arange(max_range + 1, dtype=jnp.float32) / max_range
    starts_full = jnp.searchsorted(t, edges[:-1], side="left").astype(jnp.int32)
    ends = jnp.searchsorted(t, edges[1:], side="left").astype(jnp.int32)
    counts = (ends - starts_full).astype(jnp.int32)
    counts = counts.at[-1].add(n - ends[-1])
    ss, keep = ref.stream_sample_ref(t, starts_full, counts, t_min, span,
                                     jnp.float32(multiple), max_range)
    return ss, keep.astype(bool)


# --------------------------------------------------------------- histogram
def bucket_hist(ss: jnp.ndarray, max_range: int) -> jnp.ndarray:
    """Per-bucket counts of scale stamps; returns (max_range,) int32."""
    ss = jnp.asarray(ss, jnp.int32)
    buckets = int(-(-max_range // LANE) * LANE)  # pad bucket axis to LANE
    ssp, _ = _pad_to(ss, TILE, buckets)          # pad ids out of range
    hist = bucket_hist_pallas(ssp, buckets, interpret=not _on_tpu())
    return hist[:max_range]


# -------------------------------------------------------------- volatility
def volatility_moments(q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused (Σq, Σq²) over the per-second count series."""
    q = jnp.asarray(q, jnp.float32)
    qp, n = _pad_to(q, TILE, 0.0)
    out = volatility_pallas(qp, interpret=not _on_tpu())
    return out[0], out[1]


def volatility_stats(q: jnp.ndarray) -> Tuple[float, float, float]:
    """(average, variance, std) — device-fused version of formulas (2)-(4)."""
    n = q.shape[0]
    s, s2 = volatility_moments(q)
    avg = s / n
    var = jnp.maximum(s2 / n - avg * avg, 0.0)
    return avg, var, jnp.sqrt(var)


# ------------------------------------------------------------ flash decode
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lengths: jnp.ndarray, *, block_s: int = 512) -> jnp.ndarray:
    """Blocked online-softmax GQA decode attention (see kernel docstring).

    Pads the cache axis to a block multiple; padded positions are masked by
    ``lengths`` automatically.
    """
    s = k.shape[1]
    pad = (-s) % block_s
    if pad:
        zk = jnp.zeros((k.shape[0], pad) + k.shape[2:], k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    return flash_decode_pallas(q, k, v, lengths, block_s=block_s,
                               interpret=not _on_tpu())


__all__ = [
    "bucket_hist", "flash_decode", "stream_sample", "stream_sample_ref",
    "volatility_moments", "volatility_stats",
]
