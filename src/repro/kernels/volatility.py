"""Pallas TPU kernel: fused count moments for the volatility statistics.

Tables 1-3 need Average / Variance / StdVariance of the per-second count
series q. Three separate reductions would read q from HBM three times; this
kernel computes [Σq, Σq²] in a single pass (one tile in VMEM at a time,
sequential-grid accumulation), and the wrapper derives
avg = Σq/n, var = Σq²/n − avg², std = √var.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE


def _kernel(q_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)
    s = jnp.sum(q)
    s2 = jnp.sum(q * q)
    out_ref[0, 0] += s
    out_ref[0, 1] += s2


@functools.partial(jax.jit, static_argnames=("interpret",))
def volatility_pallas(q: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """q: (n,) counts, n % TILE == 0 (zero-padded — zeros do not perturb the
    sums; the wrapper divides by the true length). Returns [Σq, Σq²] f32."""
    n = q.shape[0]
    assert n % TILE == 0, f"pad counts to a multiple of {TILE}"
    rows = n // LANE
    q2 = q.reshape(rows, LANE)
    grid = (rows // SUBLANE,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 2), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 2), jnp.float32),
        interpret=interpret,
    )(q2)
    return out.reshape(2)
