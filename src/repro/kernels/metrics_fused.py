"""Pallas TPU kernel: fused, batched stream-metrics engine.

One pass over the record tiles of ``(S, N)`` stacked scale-stamp streams
produces, per stream, BOTH reporting quantities the paper's §5.2 statistics
need:

- the per-second count histogram ``q`` (``q[b] = |{i : ss_i == b}|``), and
- its first two moments ``[Σq, Σq²]`` (formulas (2)-(4) derive avg/var/σ).

This subsumes the seed's two unwired kernels (``bucket_hist.py``,
``volatility.py``): those needed two HBM passes (records then counts), did
O(n·B) one-hot work against the *whole* bucket axis in a single VMEM block
(a (1024, 86 400) f32 one-hot is ~340 MB — a day of seconds could never
fit), and accumulated counts in float32, which silently rounds once any
bucket exceeds 2²⁴ records.

Two ops-layer entry forms feed this kernel
(:mod:`repro.kernels.ops`): ``stream_metrics_batched`` stacks host
scale-stamp arrays into the padded ``(S, N)`` layout, while
``stream_metrics_batched_device`` consumes stamps that are ALREADY on
device — the sweep engine chains it directly after the batched NSA
compaction, masking each row's invalid tail to the padding id on device,
so kept stamps never round-trip through host between NSA and metrics.

Design
------
Grid ``(stream, record-tile)`` — the same 2-D layout as
``stream_sample_pallas``, so S streams' metrics are ONE dispatch. The
histogram accumulates directly in the per-stream output block (int32 — counts
are exact up to 2³¹, enforced by the ops wrapper), which stays VMEM-resident
across the record-tile axis because its index map ignores the tile index.

The bucket axis is processed in LANE-multiple blocks of ``BUCKET_BLOCK``
inside the kernel, so the one-hot intermediate is a bounded
``(TILE, BUCKET_BLOCK)`` tile no matter how large ``max_range`` is —
``max_range`` up to the full 86 400-second day fits comfortably
(86 528 int32 ≈ 340 KiB for the resident histogram block).

Cost is data-adaptive: scale stamps are non-decreasing (Min-Max normalize is
monotone and streams are chronological), so each record tile spans a narrow
bucket range and a ``fori_loop`` with traced bounds touches only the bucket
blocks that range intersects — O(records · BUCKET_BLOCK) compare work for
sorted streams instead of O(records · max_range). Unsorted input stays
*correct* (the bounds just widen), only slower.

At the last record tile of each stream the kernel reduces the resident
histogram into ``[Σq, Σq²]``, so moments cost no extra HBM pass over either
records or counts. The reduction is f32 but uses pairwise-block + Kahan
(compensated) summation — each ``BUCKET_BLOCK`` slice collapses to one
partial, and the partials accumulate with a compensation term — so the
rounding error stays O(1) ulp regardless of the bucket-axis length (a naive
running f32 sum drifts O(B)·eps over a B = 86 400 day axis). Moments agree
with the exact f64 reference within ~1e-5 relative, an order tighter than
the 1e-3 the metrics layer historically promised.

Padding contract: the wrapper pads the record axis with bucket id
``>= buckets`` (it uses ``buckets`` itself); padded entries never match a
one-hot column and never contribute to any count or moment.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tuning import DEFAULT_CONFIG, TileConfig

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE      # records per grid step (default TileConfig)
BUCKET_BLOCK = 4 * LANE    # bucket columns per inner step (default config)


def _kernel(ss_ref, hist_ref, mom_ref, *, buckets: int, sublane: int,
            bucket_block: int):
    i = pl.program_id(1)
    num_tiles = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        mom_ref[...] = jnp.zeros_like(mom_ref)

    tile = sublane * LANE
    ss = ss_ref[0].reshape(tile)                     # (tile,) int32
    valid = ss < buckets                             # padding id >= buckets

    # data-adaptive bucket-block range: sorted stamps => a tile spans few
    # blocks; an all-padding tile runs zero iterations
    lo = jnp.min(jnp.where(valid, ss, buckets - 1)) // bucket_block
    hi = jnp.max(jnp.where(valid, ss, 0)) // bucket_block
    upper = jnp.where(jnp.any(valid), hi + 1, lo)

    def body(blk, carry):
        base = blk * bucket_block
        ids = base + jax.lax.broadcasted_iota(
            jnp.int32, (tile, bucket_block), 1)
        partial = jnp.sum((ss[:, None] == ids).astype(jnp.int32), axis=0,
                          keepdims=True)             # (1, bucket_block) int32
        cur = hist_ref[:, pl.ds(base, bucket_block)]
        hist_ref[:, pl.ds(base, bucket_block)] = cur + partial
        return carry

    jax.lax.fori_loop(lo, upper, body, 0)

    @pl.when(i == num_tiles - 1)
    def _moments():
        # pairwise-block + Kahan summation: each BUCKET_BLOCK slice reduces
        # to one f32 partial (error ~ O(log BLOCK) ulp), and the partials
        # accumulate through compensated addition — so the total error is
        # independent of the bucket-axis length instead of growing O(B)·eps
        # with a naive running f32 sum (a day-long axis has B = 86 400).
        # Tightens the engine's moment agreement from ~1e-3 to ~1e-5.
        def kahan(blk, carry):
            s1, c1, s2, c2 = carry
            q = hist_ref[:, pl.ds(blk * bucket_block, bucket_block)] \
                .astype(jnp.float32)                 # padding buckets are 0
            y1 = jnp.sum(q) - c1
            t1 = s1 + y1
            y2 = jnp.sum(q * q) - c2
            t2 = s2 + y2
            return t1, (t1 - s1) - y1, t2, (t2 - s2) - y2

        zero = jnp.float32(0.0)
        s1, _, s2, _ = jax.lax.fori_loop(
            0, buckets // bucket_block, kahan, (zero, zero, zero, zero))
        mom_ref[0, 0] = s1
        mom_ref[0, 1] = s2


def _kernel_carry(ss_ref, mcar_ref, hist_ref, mom_ref, *, buckets: int,
                  sublane: int, bucket_block: int):
    """Chunked variant of :func:`_kernel`: the final moment reduction seeds
    its pairwise+Kahan fold from a per-row carry-in ``[s1, c1, s2, c2]`` and
    emits the UPDATED 4-state instead of the bare ``[Σq, Σq²]`` pair, so
    moment accumulation composes across time chunks (histograms partition
    the bucket axis chunk-by-chunk, so chunk moments simply add; carrying
    the compensation terms keeps the error O(1) ulp over any number of
    chunks). With a zero carry the fold is bit-identical to
    :func:`_kernel`'s."""
    i = pl.program_id(1)
    num_tiles = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        mom_ref[...] = jnp.zeros_like(mom_ref)

    tile = sublane * LANE
    ss = ss_ref[0].reshape(tile)                     # (tile,) int32
    valid = ss < buckets                             # padding id >= buckets

    lo = jnp.min(jnp.where(valid, ss, buckets - 1)) // bucket_block
    hi = jnp.max(jnp.where(valid, ss, 0)) // bucket_block
    upper = jnp.where(jnp.any(valid), hi + 1, lo)

    def body(blk, carry):
        base = blk * bucket_block
        ids = base + jax.lax.broadcasted_iota(
            jnp.int32, (tile, bucket_block), 1)
        partial = jnp.sum((ss[:, None] == ids).astype(jnp.int32), axis=0,
                          keepdims=True)             # (1, bucket_block) int32
        cur = hist_ref[:, pl.ds(base, bucket_block)]
        hist_ref[:, pl.ds(base, bucket_block)] = cur + partial
        return carry

    jax.lax.fori_loop(lo, upper, body, 0)

    @pl.when(i == num_tiles - 1)
    def _moments():
        def kahan(blk, carry):
            s1, c1, s2, c2 = carry
            q = hist_ref[:, pl.ds(blk * bucket_block, bucket_block)] \
                .astype(jnp.float32)                 # padding buckets are 0
            y1 = jnp.sum(q) - c1
            t1 = s1 + y1
            y2 = jnp.sum(q * q) - c2
            t2 = s2 + y2
            return t1, (t1 - s1) - y1, t2, (t2 - s2) - y2

        s1, c1, s2, c2 = jax.lax.fori_loop(
            0, buckets // bucket_block, kahan,
            (mcar_ref[0, 0], mcar_ref[0, 1], mcar_ref[0, 2], mcar_ref[0, 3]))
        mom_ref[0, 0] = s1
        mom_ref[0, 1] = c1
        mom_ref[0, 2] = s2
        mom_ref[0, 3] = c2


@functools.partial(jax.jit,
                   static_argnames=("buckets", "interpret", "config"))
def stream_metrics_carry_pallas(ss: jnp.ndarray, mcar: jnp.ndarray,
                                buckets: int, *, interpret: bool = False,
                                config: Optional[TileConfig] = None):
    """Fused histogram + carried Kahan moments over ONE time chunk.

    ss      : (S, N) int32 chunk-LOCAL scale stamps (the caller rebases the
              chunk's absolute bucket range to [0, buckets)), N % TILE == 0;
              entries >= buckets are padding.
    mcar    : (S, 4) f32 per-row Kahan moment state ``[s1, c1, s2, c2]``
              carried from the previous chunk (zeros for the first chunk).
    buckets : chunk histogram width, % BUCKET_BLOCK == 0.

    Returns ``(hist int32 (S, buckets), mom f32 (S, 4))`` — the chunk's
    histogram plus the UPDATED Kahan state with this chunk's buckets folded
    in; ``mom[:, 0]``/``mom[:, 2]`` are the running ``Σq``/``Σq²``. With a
    zero carry, ``(hist, mom[:, ::2])`` is bit-identical to
    :func:`stream_metrics_pallas` on the same input.
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    S, n = ss.shape
    assert n % cfg.record_tile == 0, \
        f"pad records to a multiple of {cfg.record_tile}"
    assert buckets % cfg.bucket_block == 0, \
        f"pad buckets to a multiple of {cfg.bucket_block}"
    rows = n // LANE
    ss3 = ss.reshape(S, rows, LANE)
    grid = (S, rows // sublane)
    hist, mom = pl.pallas_call(
        functools.partial(_kernel_carry, buckets=buckets, sublane=sublane,
                          bucket_block=cfg.bucket_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0)),
            pl.BlockSpec((1, 4), lambda s, i: (s, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, buckets), lambda s, i: (s, 0)),
            pl.BlockSpec((1, 4), lambda s, i: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, buckets), jnp.int32),
            jax.ShapeDtypeStruct((S, 4), jnp.float32),
        ],
        interpret=interpret,
    )(ss3, mcar.astype(jnp.float32))
    return hist, mom


@functools.partial(jax.jit,
                   static_argnames=("buckets", "interpret", "config"))
def stream_metrics_pallas(ss: jnp.ndarray, buckets: int, *,
                          interpret: bool = False,
                          config: Optional[TileConfig] = None):
    """Fused batched histogram + moments over stacked scale-stamp streams.

    ss      : (S, N) int32, N % TILE == 0; entries in [0, buckets) count,
              entries >= buckets are padding and are ignored everywhere.
    buckets : histogram width, % BUCKET_BLOCK == 0 (wrapper pads + slices).

    Returns ``(hist int32 (S, buckets), moments f32 (S, 2))`` with
    ``moments[s] = [Σ_b hist[s, b], Σ_b hist[s, b]²]``.
    """
    cfg = DEFAULT_CONFIG if config is None else config
    sublane = cfg.sublane
    S, n = ss.shape
    assert n % cfg.record_tile == 0, \
        f"pad records to a multiple of {cfg.record_tile}"
    assert buckets % cfg.bucket_block == 0, \
        f"pad buckets to a multiple of {cfg.bucket_block}"
    rows = n // LANE
    ss3 = ss.reshape(S, rows, LANE)
    grid = (S, rows // sublane)
    hist, mom = pl.pallas_call(
        functools.partial(_kernel, buckets=buckets, sublane=sublane,
                          bucket_block=cfg.bucket_block),
        grid=grid,
        in_specs=[pl.BlockSpec((1, sublane, LANE), lambda s, i: (s, i, 0))],
        out_specs=[
            pl.BlockSpec((1, buckets), lambda s, i: (s, 0)),
            pl.BlockSpec((1, 2), lambda s, i: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, buckets), jnp.int32),
            jax.ShapeDtypeStruct((S, 2), jnp.float32),
        ],
        interpret=interpret,
    )(ss3)
    return hist, mom
