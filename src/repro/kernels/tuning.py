"""Shape-keyed tile autotuner for the Pallas kernel layer.

Every ``pl.pallas_call`` in :mod:`repro.kernels.stream_sample`,
:mod:`repro.kernels.metrics_fused`, :mod:`repro.kernels.trend_scan` and
:mod:`repro.kernels.compact` is parameterized on a :class:`TileConfig`
``(record_tile, bucket_block, grid_split)`` instead of hard module
constants, and this module decides which config a dispatch gets:

1. **Heuristic chooser** (``autotune="off"``, the default) — a pure
   function of the :class:`TuneKey` (problem shape pow2-snapped + device
   kind). On TPU and on the CPU ``interpret`` path it returns exactly the
   constants the kernels shipped with (``record_tile = 1024``,
   ``bucket_block = 512``, ``grid_split = 1``), so the default path is
   bit-for-bit identical to the pre-tuner kernels. GPU device kinds get a
   pow2-snapped choice (the A100-style ``_choose_pow2`` tiling-chooser
   pattern), clamped to the VMEM footprint budget.
2. **Measured sweep** (``autotune="cached"|"force"``) — a small candidate
   lattice is timed on the real device (min-of-reps), each candidate
   **oracle-gated** against the pure-jnp references in
   :mod:`repro.kernels.ref` before it is eligible (a config that is fast
   but wrong is discarded), and the winner is persisted in a JSON cache
   keyed by ``device kind + TuneKey``. ``"cached"`` reuses persisted
   winners; ``"force"`` re-measures and overwrites them.

The persisted cache lives *under the store* (``StreamStore``-adjacent):
one marker ``_markers/_tune/<device-kind>.json`` per device kind, written
through :meth:`repro.streamsim.store.StreamStore.put_marker` — the same
tempfile + ``os.replace`` atomic-write primitive the sweep service trusts
— so concurrent writers always leave a valid JSON file (in-process
writers additionally merge through a module lock, cross-process writers
are last-merge-wins). A corrupt or partially-written cache file is
*never* an error: loading falls back to the heuristic defaults.

Wiring: the ops wrappers consult the **ambient** tuner
(:func:`config_for` → :func:`current`) at every dispatch, and the layers
above (``nsa``/``metrics`` → ``engine``/``ChunkedSweepRunner`` →
``Controller.run/run_many``) accept an ``autotune=`` knob that installs a
shared tuner via :func:`tuner_context` around their device legs — so
every existing dispatch shape (monolithic, chunked, sharded, service)
inherits tuned tiles without per-call plumbing.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

LANE = 128
#: TPU sublane granularity for int32/float32 blocks: record tiles are
#: (sublane, LANE) with sublane a multiple of 8 (see the Pallas tiling
#: constraints), i.e. ``record_tile % 1024 == 0``.
MIN_RECORD_TILE = 8 * LANE

DEFAULT_RECORD_TILE = MIN_RECORD_TILE       # 1024 — the pre-tuner TILE
DEFAULT_BUCKET_BLOCK = 4 * LANE             # 512 — BUCKET_BLOCK/PAIR_TILE

#: Footprint budget for the largest tile-shaped intermediate a config can
#: make the kernels materialize (the metrics engine's one-hot
#: ``(record_tile, bucket_block)`` i32 tile): half of a TPU core's
#: ~16 MiB VMEM, leaving room for the resident histogram/Gram blocks.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

#: Kernel families a TileConfig can parameterize (TuneKey.kernel values).
KERNELS = ("stream_sample", "metrics_fused", "trend_scan", "pair_stats",
           "compact")

AUTOTUNE_MODES = ("off", "cached", "force")

#: Store marker namespace holding the per-device-kind JSON caches.
TUNE_NAMESPACE = "_tune"

#: Measured-sweep candidate axes (filtered per key by the VMEM budget and
#: the problem size — a tile wider than the padded problem never wins).
LATTICE_RECORD_TILES = (1024, 2048)
LATTICE_BUCKET_BLOCKS = (256, 512, 1024)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One kernel tiling choice: ``(record_tile, bucket_block, grid_split)``.

    record_tile  : records (or time steps) per grid step — the (sublane,
                   LANE) block height times LANE; must be a positive
                   multiple of ``MIN_RECORD_TILE`` (= 8·128 = 1024).
    bucket_block : bucket (or pair-tile) columns processed per inner step
                   — the metrics engine's one-hot width and the
                   pair-stats kernel's time tile; a positive LANE
                   multiple.
    grid_split   : number of row groups the *batch* axis of the NSA sweep
                   dispatch is split into (``1`` = today's single
                   launch); a VMEM relief valve for huge (S × tables)
                   problems.

    Frozen + hashable so it can ride ``jax.jit`` static arguments — each
    distinct config compiles its own kernel specialization.
    """

    record_tile: int = DEFAULT_RECORD_TILE
    bucket_block: int = DEFAULT_BUCKET_BLOCK
    grid_split: int = 1

    def __post_init__(self):
        if self.record_tile <= 0 or self.record_tile % MIN_RECORD_TILE:
            raise ValueError(
                f"record_tile {self.record_tile} must be a positive "
                f"multiple of {MIN_RECORD_TILE}")
        if self.bucket_block <= 0 or self.bucket_block % LANE:
            raise ValueError(
                f"bucket_block {self.bucket_block} must be a positive "
                f"multiple of {LANE}")
        if self.grid_split < 1:
            raise ValueError(f"grid_split {self.grid_split} must be >= 1")

    @property
    def sublane(self) -> int:
        """Block height of the (sublane, LANE) record tile."""
        return self.record_tile // LANE

    def vmem_bytes(self, itemsize: int = 4) -> int:
        """Footprint of the largest tile-shaped intermediate (the metrics
        one-hot ``(record_tile, bucket_block)`` tile)."""
        return self.record_tile * self.bucket_block * itemsize

    def as_dict(self) -> Dict[str, int]:
        return {"record_tile": self.record_tile,
                "bucket_block": self.bucket_block,
                "grid_split": self.grid_split}

    @classmethod
    def from_dict(cls, d: Dict) -> "TileConfig":
        return cls(record_tile=int(d["record_tile"]),
                   bucket_block=int(d["bucket_block"]),
                   grid_split=int(d.get("grid_split", 1)))


DEFAULT_CONFIG = TileConfig()


def _pow2_snap(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(int(x) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """Cache key for one tuning decision.

    Shapes are pow2-snapped so nearby problems share a cache line:
    ``s``/``n``/``r`` are the snapped stream count, record/time-axis
    length, and bucket-axis width (``r = 0`` for kernels without a bucket
    axis). ``dtype`` is the record element type name. The device kind is
    NOT part of the key — the cache file itself is per device kind.
    """

    kernel: str
    s: int
    n: int
    r: int = 0
    dtype: str = "int32"

    @classmethod
    def from_shape(cls, kernel: str, *, s: int, n: int, r: int = 0,
                   dtype: str = "int32") -> "TuneKey":
        if kernel not in KERNELS:
            raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")
        return cls(kernel=kernel, s=_pow2_snap(max(s, 1)),
                   n=_pow2_snap(max(n, 1)),
                   r=_pow2_snap(r) if r > 0 else 0, dtype=str(dtype))

    def encode(self) -> str:
        return f"{self.kernel}/s{self.s}/n{self.n}/r{self.r}/{self.dtype}"

    @classmethod
    def decode(cls, text: str) -> "TuneKey":
        kernel, s, n, r, dtype = text.split("/")
        return cls(kernel=kernel, s=int(s[1:]), n=int(n[1:]), r=int(r[1:]),
                   dtype=dtype)


def _slug(text: str) -> str:
    out = "".join(c if c.isalnum() else "-" for c in text.lower())
    while "--" in out:
        out = out.replace("--", "-")
    return out.strip("-") or "unknown"


def device_kind() -> str:
    """Cache-file identity of the accelerator the kernels dispatch to.

    ``cpu-interpret`` off-accelerator (the kernels run interpreted there,
    so timings are about interpreter overhead, not silicon — still a
    valid, self-consistent tuning target for CI), else
    ``tpu-<kind>``/``gpu-<kind>`` from the first device's
    ``device_kind``.
    """
    backend = jax.default_backend()
    if backend in ("tpu", "gpu", "cuda", "rocm"):
        family = "gpu" if backend != "tpu" else "tpu"
        try:
            kind = jax.devices()[0].device_kind
        except Exception:  # pragma: no cover - no devices at all
            kind = backend
        return _slug(f"{family}-{kind}")
    return "cpu-interpret"


def heuristic_config(key: TuneKey, kind: Optional[str] = None) -> TileConfig:
    """Pure shape-keyed chooser — the ``autotune="off"`` path.

    On TPU and the CPU interpret path this returns exactly the constants
    the kernels shipped with (``1024/512/1``), making the default
    dispatch bit-for-bit identical to the pre-tuner kernels. GPU kinds
    get a pow2-snapped choice: a fatter record tile for long record axes
    (fewer, larger programs) and a bucket block snapped to the bucket
    axis width. Every returned config satisfies the lane/sublane
    alignment invariants and the :data:`VMEM_BUDGET_BYTES` footprint
    bound (clamped bucket-block-first — the cheaper axis to shrink).
    """
    kind = device_kind() if kind is None else kind
    rt, bb = DEFAULT_RECORD_TILE, DEFAULT_BUCKET_BLOCK
    if kind.startswith("gpu"):
        rt = min(max(_pow2_snap(key.n) // 4, MIN_RECORD_TILE), 4096)
        if key.r > 0:
            bb = min(max(_pow2_snap(key.r), LANE), 8 * LANE)
    while rt * bb * 4 > VMEM_BUDGET_BYTES and bb > LANE:
        bb //= 2
    while rt * bb * 4 > VMEM_BUDGET_BYTES and rt > MIN_RECORD_TILE:
        rt //= 2
    return TileConfig(record_tile=rt, bucket_block=bb, grid_split=1)


def candidate_lattice(key: TuneKey,
                      kind: Optional[str] = None) -> List[TileConfig]:
    """Measured-sweep candidates for one key: the heuristic default plus
    the :data:`LATTICE_RECORD_TILES` × :data:`LATTICE_BUCKET_BLOCKS`
    grid, filtered by the VMEM budget and pruned to tiles no wider than
    the pow2-padded problem (a 2048-record tile cannot beat a 1024 tile
    on a 300-record stream — it only pads more)."""
    cands = [heuristic_config(key, kind)]
    rt_cap = max(_pow2_snap(key.n), MIN_RECORD_TILE)
    bb_cap = max(_pow2_snap(key.r), 2 * LANE) if key.r > 0 else LANE * 8
    for rt in LATTICE_RECORD_TILES:
        if rt > rt_cap:
            continue
        for bb in LATTICE_BUCKET_BLOCKS:
            if bb > bb_cap:
                continue
            cfg = TileConfig(record_tile=rt, bucket_block=bb)
            if cfg.vmem_bytes() <= VMEM_BUDGET_BYTES and cfg not in cands:
                cands.append(cfg)
    return cands


# --------------------------------------------------------------- sweep specs
def _interpret() -> bool:
    return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")


def _spec_rng(key: TuneKey) -> np.random.Generator:
    return np.random.default_rng(zlib.crc32(key.encode().encode()))


def _spec_shapes(key: TuneKey) -> Tuple[int, int, int]:
    """Problem sizes the sweep actually measures: the decoded key shape,
    capped so a force-sweep on an enormous key stays bounded (keys only
    differ below the caps; above them the winner generalizes)."""
    return (min(key.s, 16), min(key.n, 1 << 17),
            min(key.r, 1 << 15) if key.r > 0 else 0)


def _pad_rows(x: np.ndarray, mult: int, value) -> np.ndarray:
    pad = (-x.shape[1]) % mult
    if pad:
        fill = np.full((x.shape[0], pad), value, x.dtype)
        x = np.concatenate([x, fill], axis=1)
    return x


def _run_stream_sample(key: TuneKey, cfg: TileConfig):
    import jax.numpy as jnp

    from repro.kernels.ops import _nsa_tables
    from repro.kernels.stream_sample import stream_sample_pallas

    s, n, r = _spec_shapes(key)
    r = max(r, 2)
    rng = _spec_rng(key)
    rows = [np.sort(rng.uniform(0.0, 3600.0, n)) for _ in range(s)]
    t_b = np.empty((s, n), np.float32)
    tables = [np.empty((s, r), np.int32) for _ in range(3)]
    scal = np.empty((s, 3), np.float32)
    for i, t64 in enumerate(rows):
        t32, starts, counts, ktab, scalars = _nsa_tables(t64, r, 3.0)
        t_b[i] = t32
        tables[0][i], tables[1][i], tables[2][i] = starts, counts, ktab
        scal[i] = scalars
    tp = _pad_rows(t_b, cfg.record_tile, t_b[:, -1:].max())
    args = tuple(map(jnp.asarray, (tp, *tables, scal)))

    def run():
        ss, keep = stream_sample_pallas(*args, r, interpret=_interpret(),
                                        config=cfg)
        return ss[:, :n], keep[:, :n]

    def reference():
        from repro.kernels import ref
        out = ref.stream_sample_ref(jnp.asarray(t_b), *args[1:], r)
        return out

    return run, reference, (True, True)


def _run_metrics(key: TuneKey, cfg: TileConfig):
    import jax.numpy as jnp

    from repro.kernels.metrics_fused import stream_metrics_pallas

    s, n, r = _spec_shapes(key)
    r = max(r, 2)
    rng = _spec_rng(key)
    ss = np.sort(rng.integers(0, r, (s, n)), axis=1).astype(np.int32)
    buckets = int(-(-r // cfg.bucket_block) * cfg.bucket_block)
    ssb = jnp.asarray(_pad_rows(ss, cfg.record_tile, buckets))

    def run():
        hist, mom = stream_metrics_pallas(ssb, buckets,
                                          interpret=_interpret(), config=cfg)
        return hist[:, :r], mom

    def reference():
        from repro.kernels import ref
        hist, mom = ref.stream_metrics_ref(jnp.asarray(ss), r)
        return hist, mom

    return run, reference, (True, False)


def _run_trend_scan(key: TuneKey, cfg: TileConfig):
    import jax.numpy as jnp

    from repro.kernels.trend_scan import trend_scan_pallas

    s, n, _ = _spec_shapes(key)
    rng = _spec_rng(key)
    q = rng.integers(0, 7, (s, n)).astype(np.int32)
    qp = jnp.asarray(_pad_rows(q, cfg.record_tile, 0))

    def run():
        return (trend_scan_pallas(qp, interpret=_interpret(),
                                  config=cfg)[:, :n],)

    def reference():
        from repro.kernels import ref
        return (ref.trend_scan_ref(jnp.asarray(q)),)

    return run, reference, (True,)


def _run_pair_stats(key: TuneKey, cfg: TileConfig):
    import jax.numpy as jnp

    from repro.kernels.trend_scan import pair_stats_pallas

    s, n, _ = _spec_shapes(key)
    rng = _spec_rng(key)
    x = rng.standard_normal((s, n)).astype(np.float32)
    xp = jnp.asarray(_pad_rows(x, cfg.bucket_block, 0.0))

    def run():
        return pair_stats_pallas(xp, interpret=_interpret(), config=cfg)

    def reference():
        from repro.kernels import ref
        return ref.pair_stats_ref(jnp.asarray(x))

    return run, reference, (False, False)


def _run_compact(key: TuneKey, cfg: TileConfig):
    import jax.numpy as jnp

    from repro.kernels.compact import compact_positions_batched_pallas

    s, n, _ = _spec_shapes(key)
    rng = _spec_rng(key)
    mask = (rng.random((s, n)) < 0.3).astype(np.int32)
    mp = jnp.asarray(_pad_rows(mask, cfg.record_tile, 0))

    def run():
        pos, totals = compact_positions_batched_pallas(
            mp, interpret=_interpret(), config=cfg)
        return pos[:, :n], totals

    def reference():
        from repro.kernels import ref
        m = jnp.asarray(mask)
        incl = jnp.cumsum(m, axis=1)
        return (incl - m).astype(jnp.int32), incl[:, -1:].astype(jnp.int32)

    return run, reference, (True, True)


#: kernel name -> spec builder returning (run(cfg) closure, reference()
#: closure, per-output exactness flags). The run closure executes the real
#: Pallas wrapper with an explicit config (never the ambient tuner — no
#: recursion), the reference closure the pure-jnp oracle.
_SPECS = {
    "stream_sample": _run_stream_sample,
    "metrics_fused": _run_metrics,
    "trend_scan": _run_trend_scan,
    "pair_stats": _run_pair_stats,
    "compact": _run_compact,
}


def _outputs_match(got, want, exact_flags) -> bool:
    for g, w, exact in zip(got, want, exact_flags):
        g, w = np.asarray(g), np.asarray(w)
        if exact:
            if not np.array_equal(g, w):
                return False
        elif not np.allclose(g, w, rtol=1e-3, atol=1e-3):
            return False
    return True


# ------------------------------------------------------------------- tuner
_PERSIST_LOCK = threading.Lock()


class KernelTuner:
    """Chooses a :class:`TileConfig` per dispatch shape.

    mode  : ``"off"`` — heuristic only (zero I/O, the default);
            ``"cached"`` — in-memory → persisted cache → measured sweep;
            ``"force"`` — measured sweep, overwriting any persisted
            winner (memoized in-process so a force run sweeps each key
            once, not once per dispatch).
    store : optional :class:`repro.streamsim.store.StreamStore` the JSON
            cache persists under (``None`` = in-memory only).
    kind  : device-kind override (tests tune for a fake device; real use
            leaves the default :func:`device_kind`).
    reps  : timed repetitions per candidate; the score is the min.
    """

    def __init__(self, mode: str = "off", store=None, *,
                 kind: Optional[str] = None, reps: int = 3):
        if mode not in AUTOTUNE_MODES:
            raise ValueError(
                f"autotune mode {mode!r}; one of {AUTOTUNE_MODES}")
        self.mode = mode
        self.store = store
        self.kind = device_kind() if kind is None else kind
        self.reps = max(int(reps), 1)
        self._timer = time.perf_counter
        self._mem: Dict[TuneKey, TileConfig] = {}
        self._lock = threading.Lock()

    # -- public -----------------------------------------------------------
    def config_for(self, kernel: str, *, s: int, n: int, r: int = 0,
                   dtype: str = "int32") -> TileConfig:
        """The config a dispatch of this shape should use (may sweep)."""
        key = TuneKey.from_shape(kernel, s=s, n=n, r=r, dtype=dtype)
        if self.mode == "off":
            return heuristic_config(key, self.kind)
        with self._lock:
            hit = self._mem.get(key)
        if hit is not None:
            return hit
        if self.mode == "cached":
            disk = self._load_cache().get(key)
            if disk is not None:
                with self._lock:
                    self._mem[key] = disk
                return disk
        cfg = self._sweep(key)
        with self._lock:
            self._mem[key] = cfg
        self._persist(key, cfg)
        return cfg

    # -- measured sweep ---------------------------------------------------
    def _time_once(self, fn) -> float:
        t0 = self._timer()
        jax.block_until_ready(fn())
        return self._timer() - t0

    def _sweep(self, key: TuneKey) -> TileConfig:
        """Time the candidate lattice; oracle-gate each candidate against
        the :mod:`repro.kernels.ref` references before it is eligible.
        Any spec/measurement failure degrades to the heuristic config —
        tuning must never take a working dispatch down."""
        spec = _SPECS.get(key.kernel)
        fallback = heuristic_config(key, self.kind)
        if spec is None:
            return fallback
        best_cfg, best_t = None, float("inf")
        try:
            want = None
            for cfg in candidate_lattice(key, self.kind):
                run, reference, exact_flags = spec(key, cfg)
                out = jax.block_until_ready(run())   # compile + oracle leg
                if want is None:
                    want = jax.block_until_ready(reference())
                if not _outputs_match(out, want, exact_flags):
                    continue                          # fast-but-wrong: out
                t = min(self._time_once(run) for _ in range(self.reps))
                if t < best_t:
                    best_cfg, best_t = cfg, t
        except Exception:
            return fallback
        return best_cfg if best_cfg is not None else fallback

    # -- persistence ------------------------------------------------------
    def _load_cache(self) -> Dict[TuneKey, TileConfig]:
        """Winners persisted for this device kind; {} on any problem —
        a missing, corrupt, or partially-written cache file silently
        falls back to heuristics (it will be rewritten on the next
        sweep), never raises into a dispatch."""
        if self.store is None:
            return {}
        try:
            payload = self.store.get_marker(TUNE_NAMESPACE, self.kind)
        except Exception:
            return {}
        out: Dict[TuneKey, TileConfig] = {}
        if not isinstance(payload, dict):
            return out
        for text, entry in payload.get("entries", {}).items():
            try:
                out[TuneKey.decode(text)] = TileConfig.from_dict(entry)
            except Exception:
                continue
        return out

    def _persist(self, key: TuneKey, cfg: TileConfig) -> None:
        if self.store is None:
            return
        with _PERSIST_LOCK:
            entries = {k.encode(): c.as_dict()
                       for k, c in self._load_cache().items()}
            entries[key.encode()] = cfg.as_dict()
            self.store.put_marker(TUNE_NAMESPACE, self.kind, {
                "version": 1,
                "device_kind": self.kind,
                "entries": entries,
            })


# ------------------------------------------------------- ambient tuner knob
_DEFAULT_TUNER = KernelTuner("off")
_TLS = threading.local()


def current() -> KernelTuner:
    """The tuner ops-layer dispatches consult (innermost :func:`use`)."""
    stack = getattr(_TLS, "stack", None)
    return stack[-1] if stack else _DEFAULT_TUNER


@contextlib.contextmanager
def use(tuner: Optional[KernelTuner]):
    """Install ``tuner`` as the ambient tuner for the calling thread
    (``None`` is a no-op — callers can pass their knob through
    unconditionally)."""
    if tuner is None:
        yield
        return
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    stack.append(tuner)
    try:
        yield
    finally:
        stack.pop()


def config_for(kernel: str, *, s: int, n: int, r: int = 0,
               dtype: str = "int32") -> TileConfig:
    """Ambient-tuner shorthand the ops wrappers call at dispatch time."""
    return current().config_for(kernel, s=s, n=n, r=r, dtype=dtype)


_SHARED: Dict[Tuple[str, str, str], KernelTuner] = {}
_SHARED_LOCK = threading.Lock()


def shared_tuner(mode: str, store=None,
                 kind: Optional[str] = None) -> Optional[KernelTuner]:
    """Process-wide tuner registry: one tuner per (mode, store root,
    device kind), so repeated sweeps/engine runs share the in-memory
    winners instead of re-reading (or re-measuring) per call. ``"off"``
    maps to ``None`` — nothing to install."""
    if mode is None or mode == "off":
        if mode not in AUTOTUNE_MODES and mode is not None:
            raise ValueError(
                f"autotune mode {mode!r}; one of {AUTOTUNE_MODES}")
        return None
    root = str(getattr(store, "root", ""))
    reg_key = (mode, root, kind or device_kind())
    with _SHARED_LOCK:
        tuner = _SHARED.get(reg_key)
        if tuner is None:
            tuner = KernelTuner(mode, store=store, kind=kind)
            _SHARED[reg_key] = tuner
        return tuner


def tuner_context(autotune: Optional[str], store=None,
                  kind: Optional[str] = None):
    """``with tuning.tuner_context(autotune, store): ...`` — the one-liner
    the engine/controller layers wrap their device legs in. ``"off"`` (or
    ``None``) installs nothing; validation still runs so a typo'd mode
    fails loudly at the knob, not silently as a no-op."""
    return use(shared_tuner(autotune, store=store, kind=kind))


__all__ = [
    "AUTOTUNE_MODES", "DEFAULT_BUCKET_BLOCK", "DEFAULT_CONFIG",
    "DEFAULT_RECORD_TILE", "KERNELS", "KernelTuner", "LANE",
    "MIN_RECORD_TILE", "TUNE_NAMESPACE", "TileConfig", "TuneKey",
    "VMEM_BUDGET_BYTES", "candidate_lattice", "config_for", "current",
    "device_kind", "heuristic_config", "shared_tuner", "tuner_context",
    "use",
]
