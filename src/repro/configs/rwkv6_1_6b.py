"""rwkv6-1.6b "Finch" — attention-free, data-dependent decay
[arXiv:2404.05892; unverified]. 24L, d_model 2048, d_ff 7168, vocab 65536."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1_6b", family="ssm",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=7168, vocab_size=65_536,
        pattern=("rwkv",), rwkv_head_dim=64,
        wkv_unroll=16,  # §Perf: 13-23x lower state traffic, same math
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
        vocab_size=512, dtype="float32", loss_chunk=16)
