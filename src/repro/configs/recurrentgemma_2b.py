"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427; hf]. 26L, d_model 2560, 10H MQA (kv=1), d_ff 7680,
vocab 256000, window 2048, tied embeddings, logit softcap 30."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256_000,
        pattern=("rglru", "rglru", "local"), window=2048,
        lru_width=2560, conv_width=4, tie_embeddings=True,
        logit_softcap=30.0, rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, window=16, lru_width=64,
        dtype="float32", attn_impl="naive", loss_chunk=16)
