"""qwen3-32b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B family; hf].
64L, d_model 5120, 64H (kv=8), head_dim 128, d_ff 25600, vocab 151936."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=25_600, vocab_size=151_936,
        qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32", attn_impl="naive",
        loss_chunk=16)
