"""qwen1.5-110b — dense GQA with QKV bias [hf:Qwen/Qwen1.5 family; hf].
80L, d_model 8192, 64H (kv=8), head_dim 128, d_ff 49152, vocab 152064."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1_5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=49_152, vocab_size=152_064,
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32", attn_impl="naive",
        loss_chunk=16)
