"""deepseek-v3-671b — MLA + MoE (1 shared + 256 routed, top-8) + MTP
[arXiv:2412.19437; hf]. 61L, d_model 7168, 128 MLA heads, vocab 129280.

Assignment lists d_ff=2048: that is the per-expert (moe_intermediate_size)
width; the first_k_dense=3 dense layers use the published 18432."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=18_432, vocab_size=129_280,
        n_experts=256, top_k=8, n_shared_experts=1, d_ff_expert=2048,
        first_k_dense=3, router_score="sigmoid", capacity_factor=1.25,
        mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        mtp=True, rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, n_experts=4, top_k=2, d_ff_expert=32,
        first_k_dense=1, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        dtype="float32", attn_impl="naive", loss_chunk=16)
