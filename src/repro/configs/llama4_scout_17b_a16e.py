"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].
48L, d_model 5120, 40H (kv=8), head_dim 128, expert d_ff 8192, vocab 202048."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab_size=202_048,
        n_experts=16, top_k=1, n_shared_experts=1, d_ff_expert=8192,
        first_k_dense=0, router_score="sigmoid", capacity_factor=1.25,
        rope_theta=500_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=512, n_experts=4, top_k=1, d_ff_expert=64,
        dtype="float32", attn_impl="naive", loss_chunk=16)
