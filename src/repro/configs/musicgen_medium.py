"""musicgen-medium — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. 48L, d_model 1536, 24H MHA, d_ff 6144, vocab 2048.

Backbone only (assignment): the EnCodec frontend is a stub — input_specs()
provides precomputed frame embeddings (B, T, d_model); the LM head predicts
codebook tokens (vocab 2048)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        head_dim=64, d_ff=6144, vocab_size=2048,
        input_mode="embeddings", rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=128, dtype="float32", attn_impl="naive",
        loss_chunk=16)
