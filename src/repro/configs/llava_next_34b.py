"""llava-next-34b — VLM (anyres tiling) on a Yi-34B-class backbone
[hf:llava-hf/llava-v1.6 family; unverified].
60L, d_model 7168, 56H (kv=8), head_dim 128, d_ff 20480, vocab 64000.

Backbone only (assignment): the vision tower + anyres tiling is a stub —
input_specs() provides precomputed patch embeddings (B, T, d_model)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        head_dim=128, d_ff=20_480, vocab_size=64_000,
        input_mode="embeddings", rope_theta=5_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32", attn_impl="naive",
        loss_chunk=16)
