"""The paper's own configuration: the stream-simulation pipeline defaults
(§5 evaluation setup) — datasets, time ranges, and the consumer model used
by the end-to-end examples (a ~100M-param LM trained on simulated streams)."""

from repro.models.config import ModelConfig

DATASETS = ("sogouq", "traffic", "userbehavior")
TIME_RANGES = (600, 1200, 1800, 2400, 3000, 3600)  # the paper's six
ORIGINAL_RANGE = 86_400


def consumer_lm() -> ModelConfig:
    """~100M-parameter decoder-only LM used as the SPS task in examples."""
    return ModelConfig(
        name="stream-consumer-100m", family="dense",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32_768,
        dtype="float32", attn_impl="naive", loss_chunk=128,
        remat="none",
    )
