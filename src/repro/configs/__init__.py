"""Assigned architectures × input shapes (40 cells) + the paper's own
stream-pipeline config.

Each ``<arch>.py`` exposes ``config()`` (the exact published hyperparameters)
and ``smoke()`` (a reduced same-family config for CPU tests: float32, tiny
dims, one forward/train step must produce finite outputs).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
step input (weak-type-correct, shardable, no allocation) — the dry-run
lowers against these.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import transformer

ARCH_IDS = [
    "recurrentgemma-2b",
    "qwen3-32b",
    "qwen1_5-110b",
    "llama3-8b",
    "command-r-plus-104b",
    "rwkv6-1_6b",
    "deepseek-v3-671b",
    "llama4-scout-17b-a16e",
    "musicgen-medium",
    "llava-next-34b",
]


def _module(arch: str):
    name = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()


# --------------------------------------------------------------- the shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic decode (SSM/hybrid); decoder-only archs
    support everything else (DESIGN.md §Arch-applicability)."""
    if shape == "long_500k":
        return cfg.supports_long_context()
    return True


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs of one cell."""
    spec = SHAPES[shape]
    b, s = spec.global_batch, spec.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.input_mode == "embeddings":
        # modality frontend stub: precomputed frame/patch embeddings
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    else:
        inputs = tok
    if spec.kind == "train":
        return {"batch": {"inputs": inputs, "labels": tok}}
    if spec.kind == "prefill":
        return {"inputs": inputs,
                "lengths": jax.ShapeDtypeStruct((b,), jnp.int32)}
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s))
    if cfg.input_mode == "embeddings":
        tokens = jax.ShapeDtypeStruct((b, cfg.d_model), dt)
    else:
        tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    return {"cache": cache, "tokens": tokens}
