"""command-r-plus-104b — dense GQA, no biases
[hf:CohereForAI/c4ai-command-r family; unverified].
64L, d_model 12288, 96H (kv=8), head_dim 128, d_ff 33792, vocab 256000."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b", family="dense",
        n_layers=64, d_model=12_288, n_heads=96, n_kv_heads=8,
        head_dim=128, d_ff=33_792, vocab_size=256_000,
        rope_theta=75_000_000.0,
    )


def smoke() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, dtype="float32", attn_impl="naive",
        loss_chunk=16)
